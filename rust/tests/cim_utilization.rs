//! Acceptance tests for the reconfigurable CIM-macro subsystem (`cim`):
//! the paper's Fig. 3 claim — tile streaming's hybrid reconfigurable
//! macros raise intra-macro CIM utilization — as a measured, gated
//! artifact, plus the backend-agreement contract on every utilization
//! and Activity counter.

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::cim::ModePolicy;
use streamdcim::config::{presets, DataflowKind};
use streamdcim::{dataflow, engine};

/// On the attention presets (the 4k-token workloads behind the paper's
/// headline), reported intra-macro utilization must order strictly:
/// tile-stream > layer-stream >= non-stream.
#[test]
fn attention_presets_order_intra_macro_utilization() {
    let cfg = presets::streamdcim_default();
    for model in [presets::vilbert_base(), presets::vilbert_large()] {
        let util = |kind| dataflow::run(kind, &cfg, &model).intra_macro_utilization();
        let non = util(DataflowKind::NonStream);
        let layer = util(DataflowKind::LayerStream);
        let tile = util(DataflowKind::TileStream);
        assert!(
            tile > layer,
            "{}: tile {tile:.4} must strictly exceed layer {layer:.4}",
            model.name
        );
        assert!(
            layer >= non,
            "{}: layer {layer:.4} must be at least non {non:.4}",
            model.name
        );
        assert!(tile > 0.0 && tile <= 1.0, "{}: tile util {tile} out of range", model.name);
        assert!(non > 0.0, "{}: non-stream must still do useful work", model.name);
    }
}

/// Analytic and event backends must agree exactly on every Activity
/// counter — including the occupancy ledger the utilization metric is
/// derived from (it is a pure function of the tile schedule).
#[test]
fn backends_agree_exactly_on_utilization_counters() {
    let cfg = presets::streamdcim_default();
    let model = presets::vilbert_base();
    for kind in DataflowKind::ALL {
        let ana = dataflow::run(kind, &cfg, &model);
        let eng = engine::run(kind, &cfg, &model);
        assert_eq!(ana.activity, eng.activity, "{kind:?}: Activity diverged");
        assert_eq!(
            ana.activity.occupancy, eng.activity.occupancy,
            "{kind:?}: occupancy ledger diverged"
        );
        assert_eq!(
            ana.intra_macro_utilization(),
            eng.intra_macro_utilization(),
            "{kind:?}: utilization diverged"
        );
    }
}

/// The mode-policy ablations move utilization the way the paper says:
/// forcing normal mode (no cross-forwarding) lowers it and restores
/// replay traffic; the paper's auto reconfiguration is the best point.
#[test]
fn mode_policy_ablations_move_utilization() {
    let model = presets::vilbert_base();
    let run_with = |policy: ModePolicy| {
        let mut cfg = presets::streamdcim_default();
        cfg.features.mode_policy = policy;
        dataflow::run(DataflowKind::TileStream, &cfg, &model)
    };
    let auto = run_with(ModePolicy::Auto);
    let normal = run_with(ModePolicy::ForcedNormal);
    let forced = run_with(ModePolicy::ForcedHybrid);
    assert!(
        auto.intra_macro_utilization() > normal.intra_macro_utilization(),
        "auto {:.4} must beat forced-normal {:.4}",
        auto.intra_macro_utilization(),
        normal.intra_macro_utilization()
    );
    // cross-forwarding eliminates dynamic-operand replay: forcing
    // normal mode restores it on top of the static-weight replay both
    // configurations share
    assert!(
        normal.activity.occupancy.replay_bits > auto.activity.occupancy.replay_bits,
        "forced-normal replay {} <= auto replay {}",
        normal.activity.occupancy.replay_bits,
        auto.activity.occupancy.replay_bits
    );
    // locking every macro in hybrid mode starves static weights of
    // capacity: strictly slower than auto reconfiguration
    assert!(forced.cycles > auto.cycles, "forced {} <= auto {}", forced.cycles, auto.cycles);
    assert!(normal.cycles > auto.cycles, "normal {} <= auto {}", normal.cycles, auto.cycles);
}

/// Ragged shapes (k/n not divisible by the macro geometry) must report
/// partial-tile waste, and the counters must stay backend-identical.
#[test]
fn ragged_geometry_reports_partial_tile_waste() {
    let cfg = presets::streamdcim_default();
    let model = presets::ragged_edge();
    for kind in DataflowKind::ALL {
        let ana = dataflow::run(kind, &cfg, &model);
        let eng = engine::run(kind, &cfg, &model);
        assert_eq!(ana.activity, eng.activity, "{kind:?}: ragged Activity diverged");
        assert!(
            ana.activity.occupancy.partial_tile_waste_cells > 0,
            "{kind:?}: ragged shapes must waste edge cells"
        );
        let u = ana.intra_macro_utilization();
        assert!(u > 0.0 && u < 1.0, "{kind:?}: ragged util {u} should be interior");
    }
}
