//! Million-request-scale serving invariants: the hierarchical time-wheel
//! scheduler is differentially tested against the binary-heap reference
//! (identical pop orders, bit-identical serve artifacts), and the
//! O(1)-memory latency sketch is property-tested against exact
//! order-statistics within its documented `RELATIVE_ERROR` bound —
//! including a 100k-sample reference case and lossless merging.

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::config::{presets, DataflowKind, RoutePolicy, SchedulerKind, TenantConfig};
use streamdcim::engine::Backend;
use streamdcim::metrics::LatencyStats;
use streamdcim::prop_assert;
use streamdcim::propcheck::Prop;
use streamdcim::serve::{
    self, ArrivalKind, EventQueue, HeapQueue, ServeConfig, TimeWheel,
};

#[test]
fn prop_wheel_matches_heap_on_interleaved_workloads() {
    Prop::new("serve: time-wheel pops the heap's exact total order")
        .cases(30)
        .check(|rng| {
            let mut wheel = TimeWheel::new();
            let mut heap = HeapQueue::new();
            let mut cur = 0u64; // both queues' clock floor (last pop)
            for _round in 0..24 {
                for _ in 0..rng.range_usize(0, 8) {
                    // jump magnitudes from same-cycle to ~2^40 so events
                    // land on every wheel level
                    let magnitude = rng.range_u64(0, 40);
                    let cycle = cur + (rng.next_u64() % (1u64 << magnitude));
                    let ev = (cycle, (rng.next_u64() % 2) as u8, rng.next_u64() % 1000);
                    wheel.push(ev);
                    heap.push(ev);
                }
                prop_assert!(
                    wheel.len() == heap.len(),
                    "len diverged: wheel {} heap {}",
                    wheel.len(),
                    heap.len()
                );
                for _ in 0..rng.range_usize(0, 6) {
                    let w = wheel.pop();
                    let h = heap.pop();
                    prop_assert!(w == h, "pop diverged: wheel {w:?} heap {h:?}");
                    match w {
                        Some(ev) => cur = ev.0,
                        None => break,
                    }
                }
            }
            loop {
                let w = wheel.pop();
                let h = heap.pop();
                prop_assert!(w == h, "drain diverged: wheel {w:?} heap {h:?}");
                if w.is_none() {
                    break;
                }
            }
            Ok(())
        });
}

#[test]
fn prop_fabric_bit_identical_under_either_scheduler() {
    Prop::new("serve: wheel and heap schedulers emit byte-identical artifacts")
        .cases(12)
        .check(|rng| {
            let mut accel = presets::streamdcim_default();
            accel.serving.shards = rng.range_u64(1, 4);
            accel.serving.queue_depth = rng.range_u64(2, 24);
            accel.serving.batch_size = rng.range_u64(1, 6);
            accel.serving.arrival_seed = rng.next_u64();
            accel.serving.policy =
                RoutePolicy::ALL[rng.range_usize(0, RoutePolicy::ALL.len() - 1)];
            if rng.range_u64(0, 1) == 1 {
                accel.serving.tenants = vec![
                    TenantConfig { name: "a".into(), weight: 3, slo_cycles: 100_000 },
                    TenantConfig { name: "b".into(), weight: 1, slo_cycles: 0 },
                ];
            }
            let arrival = ArrivalKind::ALL[rng.range_usize(0, ArrivalKind::ALL.len() - 1)];
            let models = vec![presets::tiny_smoke()];
            let base_gap = serve::auto_gap(&accel, Backend::Analytic, &models);
            let mut cfg = ServeConfig {
                accel,
                models,
                dataflow: DataflowKind::ALL[rng.range_usize(0, DataflowKind::ALL.len() - 1)],
                backend: Backend::Analytic,
                arrival,
                requests: rng.range_u64(4, 64),
                mean_gap: (base_gap / 4).max(1) << rng.range_u64(0, 4),
            };
            cfg.accel.serving.scheduler = SchedulerKind::Wheel;
            let wheel = serve::simulate(&cfg).to_json().to_string_pretty();
            cfg.accel.serving.scheduler = SchedulerKind::Heap;
            let heap = serve::simulate(&cfg).to_json().to_string_pretty();
            prop_assert!(wheel == heap, "scheduler changed the artifact for {}", cfg.id());
            Ok(())
        });
}

#[test]
fn prop_latency_sketch_within_documented_error_bound() {
    Prop::new("metrics: sketch quantiles within RELATIVE_ERROR of exact, one-sided")
        .cases(20)
        .check(|rng| {
            let n = rng.range_usize(1, 5000);
            let mut vals = Vec::with_capacity(n);
            let mut sketch = LatencyStats::default();
            for _ in 0..n {
                let magnitude = rng.range_u64(0, 48);
                let v = (rng.next_u64() % (1u64 << magnitude)) + 1;
                vals.push(v);
                sketch.record(v);
            }
            vals.sort_unstable();
            for p in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let k = ((n - 1) as f64 * p).round() as usize;
                let exact = vals[k];
                let est = sketch.percentile(p);
                prop_assert!(
                    est >= exact,
                    "p{p}: estimate {est} below exact {exact} (n={n})"
                );
                let bound = (exact as f64 * (1.0 + LatencyStats::RELATIVE_ERROR)).ceil() as u64;
                prop_assert!(
                    est <= bound,
                    "p{p}: estimate {est} above bound {bound} (exact {exact}, n={n})"
                );
            }
            Ok(())
        });
}

/// The acceptance reference: 100k samples, p50/p95/p99 within the
/// documented bound of the exact order statistics, and merging two
/// half-streams reproduces the whole-stream sketch exactly.
#[test]
fn sketch_tracks_exact_quantiles_on_100k_reference() {
    const N: u64 = 100_000;
    let mut whole = LatencyStats::default();
    let mut left = LatencyStats::default();
    let mut right = LatencyStats::default();
    let mut vals = Vec::with_capacity(N as usize);
    for i in 0..N {
        // deterministic scrambled stream spanning ~7 decades
        let v = i.wrapping_mul(2654435761).wrapping_add(12345) % 10_000_000 + 1;
        whole.record(v);
        if i < N / 2 {
            left.record(v);
        } else {
            right.record(v);
        }
        vals.push(v);
    }
    vals.sort_unstable();
    let (p50, p95, p99) = whole.percentiles();
    for (p, est) in [(0.5, p50), (0.95, p95), (0.99, p99)] {
        let k = ((N - 1) as f64 * p).round() as usize;
        let exact = vals[k];
        assert!(est >= exact, "p{p}: {est} < exact {exact}");
        let bound = (exact as f64 * (1.0 + LatencyStats::RELATIVE_ERROR)).ceil() as u64;
        assert!(est <= bound, "p{p}: {est} > bound {bound} (exact {exact})");
    }
    left.merge(&right);
    assert_eq!(left, whole, "merging half-streams must be lossless");
    assert_eq!(left.count(), N);
}

#[test]
fn session_affinity_counts_rewrite_reuse() {
    let mut accel = presets::streamdcim_default();
    accel.serving.shards = 2;
    accel.serving.policy = RoutePolicy::SessionAffinity;
    accel.serving.queue_depth = 32;
    accel.serving.batch_size = 4;
    let models = vec![presets::tiny_smoke()];
    let mean_gap = serve::auto_gap(&accel, Backend::Event, &models);
    let cfg = ServeConfig {
        accel,
        models,
        dataflow: DataflowKind::TileStream,
        backend: Backend::Event,
        arrival: ArrivalKind::Poisson,
        requests: 64,
        mean_gap,
    };
    let s = serve::simulate(&cfg).stats;
    // single-model mix: every shard is warm after its first batch
    assert!(s.rewrite_reuse_batches > 0, "sticky routing must hit warm shards");
    assert!(s.rewrite_reuse_batches < s.batches, "the first batch per shard is cold");
    assert_eq!(
        s.occupancy.reused_write_bits, s.rewrite_reuse_write_bits,
        "the occupancy ledger and the reuse counter must agree"
    );
    let mut cm = serve::CostModel::new(cfg.accel.clone(), cfg.dataflow, cfg.backend);
    let c = cm.cost(&cfg.models[0]);
    if c.warm_first < c.first {
        assert!(s.rewrite_reuse_cycles_saved > 0, "warm batches must save cycles");
        assert!(s.rewrite_reuse_write_bits > 0, "warm batches must save write bits");
    }

    // the same trace under least-loaded records no reuse — warm pricing
    // is gated on the session-affinity policy
    let mut cold_cfg = cfg.clone();
    cold_cfg.accel.serving.policy = RoutePolicy::LeastLoaded;
    let cold = serve::simulate(&cold_cfg).stats;
    assert_eq!(cold.rewrite_reuse_batches, 0);
    assert_eq!(cold.rewrite_reuse_cycles_saved, 0);
    assert_eq!(cold.occupancy.reused_write_bits, 0);
}

#[test]
fn tenant_quotas_keep_a_flooded_fabric_fair() {
    let mut accel = presets::streamdcim_default();
    accel.serving.shards = 1;
    accel.serving.queue_depth = 8;
    accel.serving.batch_size = 4;
    accel.serving.tenants = vec![
        TenantConfig { name: "interactive".into(), weight: 1, slo_cycles: 1 },
        TenantConfig { name: "batch".into(), weight: 1, slo_cycles: 0 },
    ];
    let models = vec![presets::tiny_smoke()];
    let cfg = ServeConfig {
        accel,
        models,
        dataflow: DataflowKind::TileStream,
        backend: Backend::Analytic,
        arrival: ArrivalKind::Uniform,
        requests: 400,
        mean_gap: 1, // deep overload
    };
    let s = serve::simulate(&cfg).stats;
    assert_eq!(s.per_tenant.len(), 2);
    for t in &s.per_tenant {
        assert!(t.submitted > 0, "tenant {} saw no traffic", t.name);
        assert!(t.served > 0, "tenant {} starved under equal weights", t.name);
        // a completed run drains every queue: admitted => served
        assert_eq!(t.submitted, t.served + t.rejected, "{}", t.name);
    }
    let served: u64 = s.per_tenant.iter().map(|t| t.served).sum();
    let rejected: u64 = s.per_tenant.iter().map(|t| t.rejected).sum();
    assert_eq!(served, s.served);
    assert_eq!(rejected, s.rejected);
    // a 1-cycle SLO under deep overload is violated on every served
    // request of that tenant
    assert_eq!(s.per_tenant[0].slo_violations, s.per_tenant[0].served);
    assert_eq!(s.slo_violations, s.per_tenant[0].slo_violations);
    assert_eq!(s.per_tenant[0].latency.count(), s.per_tenant[0].served);
}
