//! Sweep-engine determinism: the parallel sweep over the FULL scenario
//! matrix must produce bit-identical aggregate JSON to a serial run, for
//! any thread count and any shard-shuffle seed (seeded via util/prng).

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::config::{presets, DataflowKind};
use streamdcim::sweep;
use streamdcim::util::json::Json;

#[test]
fn full_matrix_parallel_sweep_is_bit_identical_to_serial() {
    let scenarios = sweep::full_matrix(&presets::streamdcim_default());
    assert!(scenarios.len() >= 60, "matrix has only {}", scenarios.len());

    let serial = sweep::run_sweep(&scenarios, 1, 42).to_json().to_string_pretty();
    let parallel = sweep::run_sweep(&scenarios, 8, 42).to_json().to_string_pretty();
    assert_eq!(serial, parallel, "threads must not change the aggregate");

    // and the shard-shuffle seed must not either
    let reseeded = sweep::run_sweep(&scenarios, 8, 0xDEADBEEF).to_json().to_string_pretty();
    assert_eq!(serial, reseeded, "shuffle seed must not change the aggregate");

    // the output must be valid JSON of the expected shape
    let parsed = Json::parse(&serial).expect("aggregate is valid json");
    assert_eq!(
        parsed.get("scenario_count").and_then(|v| v.as_u64()),
        Some(scenarios.len() as u64)
    );

    // the utilization figure rides in every row and is therefore
    // byte-identical across threads/seeds along with the rest
    for row in parsed.get("scenarios").unwrap().as_arr().unwrap() {
        let u = row
            .get("intra_macro_utilization")
            .and_then(|v| v.as_f64())
            .expect("row missing intra_macro_utilization");
        assert!((0.0..=1.0).contains(&u), "utilization out of range: {u}");
        assert!(row.get("replay_bits").is_some(), "row missing replay_bits");
    }
}

#[test]
fn full_matrix_headline_brackets_the_paper_claims() {
    // Across the whole registry (not just the paper's two ViLBERT points)
    // the three-way ordering must hold, and the tile-vs-layer advantage
    // must stay in a plausible band around the paper's 1.28x.
    let scenarios = sweep::full_matrix(&presets::streamdcim_default());
    let report = sweep::run_sweep(&scenarios, 8, 42);
    let h = &report.headline;
    assert!(h.tile_vs_non_speedup > 1.5, "tile vs non {:.2}", h.tile_vs_non_speedup);
    assert!(h.tile_vs_layer_speedup > 1.0, "tile vs layer {:.2}", h.tile_vs_layer_speedup);
    assert!(h.tile_vs_non_energy > 1.0, "energy vs non {:.2}", h.tile_vs_non_energy);
    assert!(h.tile_vs_layer_energy > 1.0, "energy vs layer {:.2}", h.tile_vs_layer_energy);

    // tile/full must out-rank both baselines in the group ranking
    let rank = |df: DataflowKind| {
        report
            .groups
            .iter()
            .find(|g| g.dataflow == df && g.ablation == "full")
            .map(|g| g.rank)
            .unwrap()
    };
    assert!(rank(DataflowKind::TileStream) < rank(DataflowKind::LayerStream));
    assert!(rank(DataflowKind::LayerStream) < rank(DataflowKind::NonStream));
}

#[test]
fn ablations_cost_performance_on_paper_scale_workloads() {
    // On ViLBERT-base the feature ablations must each lose to tile/full
    // (the paper's claim that every mechanism contributes).
    let scenarios =
        sweep::matrix_for(&presets::streamdcim_default(), &[presets::vilbert_base()]);
    let report = sweep::run_sweep(&scenarios, 4, 42);
    let speed = |ablation: &str| {
        report
            .rows
            .iter()
            .find(|r| {
                r.result.report.dataflow == DataflowKind::TileStream
                    && r.result.ablation == ablation
            })
            .map(|r| r.speedup_vs_non)
            .unwrap()
    };
    let full = speed("full");
    for ablation in ["no-pruning", "no-pingpong", "no-hybrid", "forced-hybrid"] {
        assert!(
            speed(ablation) < full,
            "{ablation} ({:.3}) should lose to full ({full:.3})",
            speed(ablation)
        );
    }
    // a wider write port can only help rewrite-bound schedules
    assert!(speed("fast-port") >= full, "fast-port should not lose to full");
}
