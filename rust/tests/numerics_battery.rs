//! The numerics test battery: property checks over the microscaling
//! quantizer, the seeded readout non-idealities, and the accuracy proxy
//! (`streamdcim::numerics`), plus the end-to-end contract that accuracy
//! fields in sweep artifacts are byte-identical across thread counts.
//!
//! The monotonicity checks are property tests over many random tensors,
//! shapes, and block sizes (seeded by the repo's own PRNG — no ambient
//! randomness, every case reproducible by its printed seed).

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::config::{presets, PrecisionConfig};
use streamdcim::model::refimpl::{self, BlockWeights, Mat};
use streamdcim::numerics::{
    accuracy_proxy, effective_model, quantized_encoder, AccuracyReport, MxFormat, Readout,
};
use streamdcim::sweep::{matrix_for, run_sweep};
use streamdcim::util::prng::Rng;

fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = *x as f64 - *y as f64;
            d * d
        })
        .sum::<f64>()
        / a.len().max(1) as f64
}

fn random_tensor(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (rng.normal() * 2.0) as f32).collect()
}

#[test]
fn fp32_default_is_bit_exact_not_just_close() {
    // the identity contract: with the default precision config the hook
    // path must produce the *same bits* as the plain reference — not a
    // small error, zero error
    let cfg = presets::streamdcim_default();
    let mut rng = Rng::new(0xbeef);
    let w = BlockWeights::random(&mut rng, 32, 64);
    let ix = Mat::random_i16_grid(&mut rng, 8, 32, 0.5);
    let iy = Mat::random_i16_grid(&mut rng, 12, 32, 0.5);
    let (reference, _) = refimpl::encoder_block(&w, &ix, &iy, 4);
    let (observed, _) = quantized_encoder(&cfg, &w, &ix, &iy, 4);
    let ref_bits: Vec<u32> = reference.data.iter().map(|v| v.to_bits()).collect();
    let obs_bits: Vec<u32> = observed.data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(ref_bits, obs_bits, "fp32 hook path must be the exact identity");
    // and the proxy reports exactly-zero error on every workload class
    let models =
        [presets::vilbert_base(), presets::tiny_smoke(), presets::trancim_microbench()];
    for model in models {
        let acc = accuracy_proxy(&cfg, &model);
        assert_eq!(acc.mse, 0.0, "{}: fp32 proxy error must be exactly 0", model.name);
        assert_eq!(acc.sqnr_db, AccuracyReport::IDEAL_SQNR_DB, "{}", model.name);
        assert_eq!(acc.effective_bits, model.bits, "{}", model.name);
    }
}

#[test]
fn quantization_mse_monotone_non_increasing_in_mantissa_bits() {
    // property test: the representable grid at m+1 mantissa bits nests
    // the grid at m (the step is a power of two and the shared exponent
    // is mantissa-independent), so round-to-nearest error can only fall
    let mut meta = Rng::new(0x5eed);
    for case in 0..24u64 {
        let n = 64 + (meta.next_u64() % 4000) as usize;
        let block = [1usize, 2, 8, 16, 32, 64][(meta.next_u64() % 6) as usize];
        let xs = random_tensor(0x1000 + case, n);
        let mut prev = f64::INFINITY;
        for m in 1..=12u32 {
            let f = MxFormat { mantissa_bits: m, shared_exp_block: block };
            let mut q = xs.clone();
            f.quantize(&mut q);
            let e = mse(&xs, &q);
            assert!(
                e <= prev,
                "case {case} (n={n}, block={block}): mantissa {m} raised MSE {e:.3e} > {prev:.3e}"
            );
            prev = e;
        }
    }
}

#[test]
fn variation_mse_monotone_non_decreasing_in_sigma() {
    // property test: with the same seeded gaussian stream the per-value
    // perturbation is x * sigma * g, so MSE scales with sigma^2 exactly
    let mut meta = Rng::new(0xda7a);
    for case in 0..16u64 {
        let n = 128 + (meta.next_u64() % 2048) as usize;
        let xs = random_tensor(0x2000 + case, n);
        let mut prev = -1.0;
        for k in 0..=6 {
            let sigma = 0.004 * k as f64;
            let r = Readout { levels: u64::MAX, sigma };
            let mut noisy = xs.clone();
            r.variation(&mut noisy, &mut Rng::new(0x77));
            let e = mse(&xs, &noisy);
            assert!(
                e >= prev,
                "case {case} (n={n}): sigma {sigma} lowered MSE {e:.3e} < {prev:.3e}"
            );
            prev = e;
        }
    }
}

#[test]
fn adc_error_monotone_non_increasing_in_level_count() {
    // power-of-two level counts nest their uniform grids: doubling the
    // levels halves the step, and every old code stays representable
    let xs = random_tensor(9, 2048);
    let mut prev = f64::INFINITY;
    for k in 2..=14u32 {
        let r = Readout { levels: 1u64 << k, sigma: 0.0 };
        let mut q = xs.clone();
        r.adc_quantize(&mut q);
        let e = mse(&xs, &q);
        assert!(e <= prev, "levels 2^{k}: MSE {e:.3e} > {prev:.3e}");
        prev = e;
    }
}

#[test]
fn format_ladder_orders_the_accuracy_proxy() {
    // mx4 < mx6 < mx8 < fp32 in SQNR (and the reverse in MSE) on the
    // 16-bit paper workloads — the trade-off surface the DSE explores
    for model in [presets::vilbert_base(), presets::tiny_smoke()] {
        let score = |slug: &str| {
            let mut cfg = presets::streamdcim_default();
            cfg.precision = PrecisionConfig::parse(slug).unwrap();
            accuracy_proxy(&cfg, &model)
        };
        let (a4, a6, a8, afp) = (score("mx4"), score("mx6"), score("mx8"), score("fp32"));
        let name = &model.name;
        assert!(a4.sqnr_db < a6.sqnr_db, "{name}: mx4 {} >= mx6 {}", a4.sqnr_db, a6.sqnr_db);
        assert!(a6.sqnr_db < a8.sqnr_db, "{name}: mx6 {} >= mx8 {}", a6.sqnr_db, a8.sqnr_db);
        assert!(a8.sqnr_db < afp.sqnr_db, "{name}: mx8 {} >= fp32 {}", a8.sqnr_db, afp.sqnr_db);
        assert!(a4.mse > a6.mse && a6.mse > a8.mse && a8.mse > afp.mse, "{name}");
        assert_eq!(afp.mse, 0.0);
        assert!(a4.effective_bits < a6.effective_bits);
        assert!(a6.effective_bits < a8.effective_bits);
    }
}

#[test]
fn readout_noise_widens_the_proxy_error_and_is_seed_deterministic() {
    let model = presets::tiny_smoke();
    // sigma 0 with noise on: the ADC alone already costs accuracy
    let mut adc_only = presets::streamdcim_default();
    adc_only.precision.noise = true;
    adc_only.precision.noise_sigma = 0.0;
    let quiet = accuracy_proxy(&adc_only, &model);
    assert!(quiet.mse > 0.0, "ADC quantization must be visible in the proxy");
    // device variation on top strictly widens the error
    let mut noisy_cfg = adc_only.clone();
    noisy_cfg.precision.noise_sigma = 0.04;
    let noisy = accuracy_proxy(&noisy_cfg, &model);
    assert!(noisy.mse > quiet.mse, "sigma 0.04 {} <= ADC-only {}", noisy.mse, quiet.mse);
    // and the whole thing is a pure function of the config
    assert_eq!(noisy, accuracy_proxy(&noisy_cfg, &model));
    let mut reseeded = noisy_cfg.clone();
    reseeded.precision.noise_seed = 1234;
    assert_ne!(accuracy_proxy(&reseeded, &model).mse, noisy.mse, "seed must steer the draw");
}

#[test]
fn effective_model_cap_is_idempotent_for_every_format() {
    for slug in ["fp32", "mx8", "mx6", "mx4", "fp32-noisy", "mx4-noisy"] {
        let mut cfg = presets::streamdcim_default();
        cfg.precision = PrecisionConfig::parse(slug).unwrap();
        for model in [presets::vilbert_base(), presets::trancim_microbench()] {
            let once = effective_model(&cfg, &model);
            let twice = effective_model(&cfg, &once);
            assert_eq!(once, twice, "{slug}/{}: the bit cap must be idempotent", model.name);
            assert!(once.bits <= model.bits, "{slug}/{}: the cap never widens", model.name);
        }
    }
}

#[test]
fn sweep_accuracy_fields_are_byte_identical_across_thread_counts() {
    // the determinism contract extended to the numerics axis: a noisy
    // reduced-precision sweep must aggregate to the same bytes no matter
    // how the scenarios were sharded
    let mut accel = presets::streamdcim_default();
    accel.precision = PrecisionConfig::parse("mx4-noisy").unwrap();
    let scenarios = matrix_for(&accel, &[presets::tiny_smoke(), presets::functional_small()]);
    let one = run_sweep(&scenarios, 1, 42);
    let eight = run_sweep(&scenarios, 8, 42);
    let mut a = Vec::new();
    let mut b = Vec::new();
    one.write_jsonl(&mut a).unwrap();
    eight.write_jsonl(&mut b).unwrap();
    assert_eq!(a, b, "sweep artifact must not depend on the thread count");
    let text = String::from_utf8(a).unwrap();
    assert!(text.contains("\"accuracy_mse\""), "rows must carry accuracy_mse");
    assert!(text.contains("\"accuracy_sqnr_db\""), "rows must carry accuracy_sqnr_db");
    assert!(text.contains("\"effective_bits\""), "rows must carry effective_bits");
    // quantization + noise priced in: no scenario reports the ideal cap
    for row in &one.rows {
        assert!(row.result.report.accuracy.mse > 0.0, "{}", row.result.id);
        assert!(
            row.result.report.accuracy.sqnr_db < AccuracyReport::IDEAL_SQNR_DB,
            "{}",
            row.result.id
        );
    }
}
