//! Full-stack simulator integration tests: the paper's headline claims
//! (experiments E3-E6 in DESIGN.md) within reproduction bands.

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::config::{presets, DataflowKind};
use streamdcim::model::{Op, OpKind, Stream};
use streamdcim::report;
use streamdcim::sim::OpTiling;
use streamdcim::util::geomean;

fn runs_for(model: streamdcim::config::ModelConfig) -> Vec<streamdcim::metrics::RunReport> {
    report::run_all(&presets::streamdcim_default(), &model)
}

#[test]
fn e3_fig6_speedup_bands() {
    // Paper Fig. 6: 2.86x/1.25x (base), 2.42x/1.31x (large).
    // Reproduction band: ordering exact, magnitudes within ~0.5x..2x.
    let base = runs_for(presets::vilbert_base());
    let (s_non, s_layer) = report::speedups(&base);
    assert!(s_non > 2.0 && s_non < 4.5, "base vs Non-stream: {s_non:.2} (paper 2.86)");
    assert!(s_layer > 1.1 && s_layer < 1.8, "base vs Layer-stream: {s_layer:.2} (paper 1.25)");

    let large = runs_for(presets::vilbert_large());
    let (l_non, l_layer) = report::speedups(&large);
    assert!(l_non > 2.0 && l_non < 4.5, "large vs Non-stream: {l_non:.2} (paper 2.42)");
    assert!(l_layer > 1.1 && l_layer < 1.8, "large vs Layer-stream: {l_layer:.2} (paper 1.31)");
}

#[test]
fn e4_fig7_energy_bands() {
    // Paper Fig. 7: 2.64x/1.27x (base), 1.94x/1.19x (large).
    let base = runs_for(presets::vilbert_base());
    let (e_non, e_layer) = report::energy_savings(&base);
    assert!(e_non > 1.8 && e_non < 4.5, "base energy vs Non-stream: {e_non:.2} (paper 2.64)");
    assert!(
        e_layer > 1.05 && e_layer < 1.6,
        "base energy vs Layer-stream: {e_layer:.2} (paper 1.27)"
    );

    let large = runs_for(presets::vilbert_large());
    let (f_non, f_layer) = report::energy_savings(&large);
    assert!(f_non > 1.5 && f_non < 4.0, "large energy vs Non-stream: {f_non:.2} (paper 1.94)");
    assert!(
        f_layer > 1.05 && f_layer < 1.6,
        "large energy vs Layer-stream: {f_layer:.2} (paper 1.19)"
    );
}

#[test]
fn e6_headline_geomeans() {
    // Paper conclusion: geomean 2.63x / 1.28x speedup, 2.26x / 1.23x energy.
    let base = runs_for(presets::vilbert_base());
    let large = runs_for(presets::vilbert_large());
    let sp = [report::speedups(&base), report::speedups(&large)];
    let en = [report::energy_savings(&base), report::energy_savings(&large)];
    let g_sp_non = geomean(&sp.iter().map(|p| p.0).collect::<Vec<_>>());
    let g_sp_layer = geomean(&sp.iter().map(|p| p.1).collect::<Vec<_>>());
    let g_en_non = geomean(&en.iter().map(|p| p.0).collect::<Vec<_>>());
    let g_en_layer = geomean(&en.iter().map(|p| p.1).collect::<Vec<_>>());
    println!("geomean speedup {g_sp_non:.2}/{g_sp_layer:.2}, energy {g_en_non:.2}/{g_en_layer:.2}");
    assert!(g_sp_non > 2.0 && g_sp_non < 4.0, "paper 2.63, got {g_sp_non:.2}");
    assert!(g_sp_layer > 1.1 && g_sp_layer < 1.7, "paper 1.28, got {g_sp_layer:.2}");
    assert!(g_en_non > 1.7 && g_en_non < 4.0, "paper 2.26, got {g_en_non:.2}");
    assert!(g_en_layer > 1.05 && g_en_layer < 1.5, "paper 1.23, got {g_en_layer:.2}");
}

#[test]
fn e5_trancim_rewrite_fraction() {
    // Paper Sec. I: with 512-bit bandwidth, QK^T on a 2048x512 INT8 K
    // matrix spends >57 % of its latency rewriting K in CIM macros.
    let cfg = presets::streamdcim_default();
    let op = Op {
        name: "qkt",
        kind: OpKind::MatMulDynamic,
        stream: Stream::X,
        batch: 1,
        m: 2048,
        k: 512,
        n: 2048,
        bits: 8,
    };
    let t = OpTiling::of(&cfg, &op);
    let rewrite = t.rewrite_cycles(&cfg) as f64;
    let compute = t.compute_cycles(cfg.macros_per_core) as f64;
    let frac = rewrite / (rewrite + compute);
    assert!(frac > 0.57, "rewrite fraction {frac:.3}");

    // And Sec. I's compute-share claim: QK^T is 66.7 % of the MACs when
    // Q and K generation are included.
    let qkt_macs = (2048u64 * 512 * 2048) as f64;
    let gen_macs = 2.0 * (2048u64 * 512 * 512) as f64;
    assert!((qkt_macs / (qkt_macs + gen_macs) - 2.0 / 3.0).abs() < 1e-9);
}

#[test]
fn fig5_area_and_power_totals() {
    use streamdcim::energy::area::AreaModel;
    let cfg = presets::streamdcim_default();
    let total = AreaModel::default().total_mm2(&cfg);
    assert!((total - 12.10).abs() < 0.2, "area {total:.2} mm^2 (paper 12.10)");

    // Peak on-chip power in the same regime as the paper's 122.77 mW max.
    let runs = runs_for(presets::vilbert_base());
    let tile = runs.iter().find(|r| r.dataflow == DataflowKind::TileStream).unwrap();
    let onchip_mw = tile.energy.onchip_mj() / tile.energy.ms * 1e3;
    assert!(
        onchip_mw > 60.0 && onchip_mw < 190.0,
        "on-chip power {onchip_mw:.1} mW (paper max 122.77)"
    );
}

#[test]
fn pruning_contributes_but_is_not_the_whole_story() {
    // StreamDCIM must beat Layer-stream even with the DTPU disabled —
    // the dataflow/pipeline contributions stand alone (paper challenges 2-3).
    let mut cfg = presets::streamdcim_default();
    cfg.features.token_pruning = false;
    let model = presets::vilbert_base();
    let runs = report::run_all(&cfg, &model);
    let (_, s_layer) = report::speedups(&runs);
    assert!(s_layer > 1.05, "no-pruning tile vs layer: {s_layer:.3}");

    // and pruning adds on top
    let cfg_p = presets::streamdcim_default();
    let runs_p = report::run_all(&cfg_p, &model);
    let (_, s_layer_p) = report::speedups(&runs_p);
    assert!(s_layer_p > s_layer, "pruning should add speedup: {s_layer_p:.3} vs {s_layer:.3}");
}

#[test]
fn utilization_is_sane() {
    let runs = runs_for(presets::vilbert_base());
    for r in &runs {
        for (name, u) in &r.utilization {
            assert!((0.0..=1.0).contains(u), "{} utilization {u} in {}", name, r.dataflow.name());
        }
        // cores must be meaningfully busy in streaming modes
        if r.dataflow != DataflowKind::NonStream {
            let tbr = r.utilization.iter().find(|(n, _)| n == "TBR-CIM").unwrap().1;
            assert!(tbr > 0.2, "TBR-CIM idle ({tbr:.2}) under {}", r.dataflow.name());
        }
    }
}

#[test]
fn per_layer_stats_cover_the_run() {
    let runs = runs_for(presets::vilbert_base());
    for r in &runs {
        assert_eq!(r.per_layer.len() as u64, 6 + 12 + 6);
        assert!(r.per_layer.iter().all(|l| l.end > l.start));
        for w in r.per_layer.windows(2) {
            assert!(w[1].start >= w[0].start, "layers out of order in {}", r.dataflow.name());
        }
        let last_end = r.per_layer.iter().map(|l| l.end).max().unwrap();
        assert!(last_end <= r.cycles);
    }
}

#[test]
fn report_renders_all_figures() {
    let cfg = presets::streamdcim_default();
    let base = runs_for(presets::vilbert_base());
    let tile = base.iter().find(|r| r.dataflow == DataflowKind::TileStream).unwrap();
    let f5 = report::fig5(&cfg, tile);
    assert!(f5.body.contains("paper: 12.10"));
    let all = vec![
        ("ViLBERT-base".to_string(), base),
        ("ViLBERT-large".to_string(), runs_for(presets::vilbert_large())),
    ];
    assert!(report::fig6(&all).body.contains("geomean speedup"));
    assert!(report::fig7(&all).body.contains("geomean energy saving"));
    assert!(report::headline(&all).body.contains("geomean"));
}
