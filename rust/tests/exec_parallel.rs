//! The persistent work-stealing executor behind every parallel
//! subsystem: one process-wide pool now serves `sweep`, `serve
//! --matrix`, and `dse` back to back, so this test drives all three
//! through the SAME pool in one process and asserts every artifact is
//! byte-identical between 1 and 8 threads.  (The per-subsystem
//! determinism tests cover each in isolation; this one covers the
//! sharing — worker reuse, deque recycling, and interleaved submission
//! must never leak between callers.)

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::config::presets;
use streamdcim::dse;
use streamdcim::engine::Backend;
use streamdcim::exec;
use streamdcim::serve;
use streamdcim::sweep;

#[test]
fn sweep_serve_and_dse_are_bit_identical_through_the_shared_pool() {
    let accel = presets::streamdcim_default();

    // 1) engine-level sweep
    let scenarios = sweep::matrix_for(&accel, &[presets::tiny_smoke()]);
    let sweep_t1 = sweep::run_sweep(&scenarios, 1, 42).to_json().to_string_pretty();
    let sweep_t8 = sweep::run_sweep(&scenarios, 8, 42).to_json().to_string_pretty();
    assert_eq!(sweep_t1, sweep_t8, "sweep artifact changed with thread count");

    // 2) serving matrix (the `serve --matrix` path)
    let serve_scenarios = serve::serve_matrix(&accel, Backend::Analytic, 48);
    let serve_t1 = serve::run_serve_sweep(&serve_scenarios, 1, 42).to_json().to_string_pretty();
    let serve_t8 = serve::run_serve_sweep(&serve_scenarios, 8, 42).to_json().to_string_pretty();
    assert_eq!(serve_t1, serve_t8, "serve matrix artifact changed with thread count");

    // 3) design-space exploration
    let cfg = dse::DseConfig {
        accel: accel.clone(),
        model: presets::tiny_smoke(),
        objectives: vec![dse::Objective::Cycles, dse::Objective::Energy],
        backends: vec![Backend::Analytic],
        budget: 16,
        serve_requests: 16,
        seed: 42,
        two_phase: true,
        dominance_slack: dse::DEFAULT_DOMINANCE_SLACK,
    };
    let dse_t1 = dse::explore(&cfg, 1).to_json().to_string_pretty();
    let dse_t8 = dse::explore(&cfg, 8).to_json().to_string_pretty();
    assert_eq!(dse_t1, dse_t8, "dse artifact changed with thread count");

    // and a different shard-shuffle seed must not change any of them
    let reseeded = sweep::run_sweep(&scenarios, 8, 0xFEED).to_json().to_string_pretty();
    assert_eq!(sweep_t1, reseeded, "shuffle seed leaked into the sweep artifact");
}

#[test]
fn concurrent_callers_share_the_pool_without_cross_talk() {
    // several OS threads each run their own ordered batch on the shared
    // pool at the same time; every batch must come back in job order
    let handles: Vec<_> = (0..4u64)
        .map(|c| {
            std::thread::spawn(move || {
                let jobs: Vec<Box<dyn FnOnce() -> u64 + Send>> = (0..64u64)
                    .map(|i| Box::new(move || c * 1000 + i) as Box<dyn FnOnce() -> u64 + Send>)
                    .collect();
                exec::run_ordered(jobs, 8, c)
            })
        })
        .collect();
    for (c, h) in handles.into_iter().enumerate() {
        let got = h.join().expect("caller thread");
        let want: Vec<u64> = (0..64u64).map(|i| c as u64 * 1000 + i).collect();
        assert_eq!(got, want, "caller {c} got jobs out of order");
    }
    // the pool never shrinks and never exceeds its cap
    assert!(exec::pool().workers() <= exec::MAX_WORKERS);
}
