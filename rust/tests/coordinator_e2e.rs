//! End-to-end coordinator tests: the serving path over real PJRT
//! artifacts, with DTPU pruning between stages (needs `make artifacts`;
//! the refimpl-backed tests always run).  Every batch is additionally
//! priced in engine cycles — the coordinator and the serving fabric
//! share one cost model.

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use std::path::{Path, PathBuf};

use streamdcim::config::presets;
use streamdcim::coordinator::{Coordinator, CoordinatorConfig, Request};
use streamdcim::model::refimpl::Mat;
use streamdcim::util::prng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn request(id: u64, rng: &mut Rng) -> Request {
    Request {
        id,
        ix: Mat::random_i16_grid(rng, 128, 128, 0.5),
        iy: Mat::random_i16_grid(rng, 128, 128, 0.5),
    }
}

#[test]
fn pjrt_serving_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let model = presets::functional_small();
    let cfg = CoordinatorConfig::with_artifacts(dir, vec![128, 96, 64], 4, 42);
    let coord = Coordinator::start(cfg, &model).expect("coordinator start");
    let mut rng = Rng::new(7);
    let waiters: Vec<_> = (0..8).map(|i| coord.submit(request(i, &mut rng))).collect();
    for (i, w) in waiters.into_iter().enumerate() {
        let resp = w.recv().expect("leader alive").expect("forward ok");
        assert_eq!(resp.id, i as u64);
        assert_eq!(resp.stages, vec![128, 96, 64], "pruning stages traversed");
        assert_eq!(resp.x.rows, 64);
        assert_eq!(resp.y.rows, 64);
        assert!(resp.x.data.iter().all(|v| v.is_finite()));
        assert!(resp.exec_us > 0);
        assert!(resp.batch_sim_cycles > 0, "every batch is engine-priced");
    }
    let stats = coord.shutdown();
    assert_eq!(stats.served, 8);
    assert!(stats.mean_batch() >= 1.0);
    assert!(stats.sim_cycles > 0);
}

#[test]
fn pjrt_serving_matches_refimpl_serving() {
    // Same seed => same weights and same inputs; PJRT path and refimpl
    // path must agree on outputs (tolerance) and pruning decisions.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let model = presets::functional_small();
    let run = |artifacts: Option<PathBuf>| {
        let mut cfg = CoordinatorConfig::reference(vec![128, 96, 64], 1, 42);
        cfg.artifact_dir = artifacts;
        let coord = Coordinator::start(cfg, &model).unwrap();
        let mut rng = Rng::new(8);
        let resp = coord.submit(request(0, &mut rng)).recv().unwrap().unwrap();
        coord.shutdown();
        resp
    };
    let pjrt = run(Some(dir));
    let rref = run(None);
    assert_eq!(pjrt.stages, rref.stages);
    assert_eq!(pjrt.x.rows, rref.x.rows);
    // identical cost-model inputs => identical engine pricing
    assert_eq!(pjrt.batch_sim_cycles, rref.batch_sim_cycles);
    let max_diff = pjrt
        .x
        .data
        .iter()
        .zip(&rref.x.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    // pruning keeps discrete token sets; if a borderline score flips a
    // token the outputs differ structurally — accept either bitwise-near
    // agreement or identical shapes with small aggregate drift
    let mean_diff: f32 = pjrt
        .x
        .data
        .iter()
        .zip(&rref.x.data)
        .map(|(a, b)| (a - b).abs())
        .sum::<f32>()
        / pjrt.x.data.len() as f32;
    assert!(
        max_diff < 0.05 || mean_diff < 0.02,
        "PJRT vs refimpl diverged: max {max_diff}, mean {mean_diff}"
    );
}

#[test]
fn refimpl_serving_under_load() {
    let model = presets::functional_small();
    let coord =
        Coordinator::start(CoordinatorConfig::reference(vec![128, 96, 64], 8, 1), &model).unwrap();
    let mut rng = Rng::new(2);
    let waiters: Vec<_> = (0..32).map(|i| coord.submit(request(i, &mut rng))).collect();
    let mut max_batch = 0;
    for w in waiters {
        let r = w.recv().unwrap().unwrap();
        max_batch = max_batch.max(r.batch_size);
    }
    let stats = coord.shutdown();
    assert_eq!(stats.served, 32);
    assert!(stats.batches < 32, "burst must produce multi-request batches");
    assert!(max_batch > 1);
    assert!(stats.percentile_us(0.95) >= stats.percentile_us(0.5));
    assert!(stats.latency_us.p99() >= stats.latency_us.p95());
    // batching amortizes pipeline fill: priced cycles beat 32 solo runs
    let solo = streamdcim::serve::CostModel::new(
        presets::streamdcim_default(),
        streamdcim::config::DataflowKind::TileStream,
        streamdcim::engine::Backend::Event,
    )
    .cost(&model)
    .batch_cycles(1);
    assert!(
        stats.sim_cycles <= 32 * solo,
        "batched {} cycles must not exceed {} solo cycles",
        stats.sim_cycles,
        32 * solo
    );
    assert!(stats.served_per_busy_megacycle() > 0.0);
}

#[test]
fn coordinator_survives_drop_without_shutdown() {
    let model = presets::functional_small();
    let coord =
        Coordinator::start(CoordinatorConfig::reference(vec![128, 96, 64], 2, 3), &model).unwrap();
    let mut rng = Rng::new(4);
    let w = coord.submit(request(0, &mut rng));
    let _ = w.recv().unwrap().unwrap();
    drop(coord); // Drop impl joins the leader — must not hang or panic
}
