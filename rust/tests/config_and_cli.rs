//! Config-file + CLI integration: the `configs/` examples must parse and
//! produce runnable configurations.

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::cli;
use streamdcim::config::{presets, toml};

#[test]
fn shipped_config_files_parse_and_apply() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut found = 0;
    for entry in std::fs::read_dir(&dir).expect("configs/ dir") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        found += 1;
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = toml::parse(&text).unwrap_or_else(|e| panic!("{path:?}: {e}"));
        let mut accel = presets::streamdcim_default();
        let mut model = presets::vilbert_base();
        toml::apply_accel_overrides(&mut accel, &doc);
        toml::apply_model_overrides(&mut model, &doc);
        assert!(accel.cores > 0 && accel.freq_mhz > 0, "{path:?} broke the accel config");
        assert!(model.tokens_x > 0, "{path:?} broke the model config");
    }
    assert!(found >= 2, "expected at least 2 example configs, found {found}");
}

#[test]
fn cli_full_command_lines() {
    let argv: Vec<String> = ["run", "--model", "large", "--dataflow", "layer", "--json"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let a = cli::parse(argv).unwrap();
    assert_eq!(a.command, "run");
    assert_eq!(a.flag("model"), Some("large"));
    assert_eq!(a.flag("dataflow"), Some("layer"));
    assert!(a.has("json"));

    let argv: Vec<String> = [
        "serve",
        "--shards",
        "4",
        "--policy",
        "least-loaded",
        "--arrival",
        "poisson",
        "--requests=16",
        "--matrix",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let a = cli::parse(argv).unwrap();
    assert_eq!(a.flag_u64("requests", 0), 16);
    assert_eq!(a.flag_u64("shards", 0), 4);
    assert_eq!(a.flag("policy"), Some("least-loaded"));
    assert!(a.has("matrix"));
}

#[test]
fn ablation_config_disables_features() {
    // hybrid_mode=false is the deprecated TOML alias for the forced-normal
    // macro mode policy (cim::ModePolicy)
    let text = "[features]\nhybrid_mode = false\npingpong = false\ntoken_pruning = false\n";
    let doc = toml::parse(text).unwrap();
    let mut accel = presets::streamdcim_default();
    toml::apply_accel_overrides(&mut accel, &doc);
    assert_eq!(accel.features.mode_policy, streamdcim::cim::ModePolicy::ForcedNormal);
    assert!(!accel.features.pingpong);
    assert!(!accel.features.token_pruning);
}

#[test]
fn macro_section_configures_geometry_and_mode_policy() {
    let text = "[macro]\nsub_arrays = 4\narray_cols = 64\nmode_policy = \"hybrid\"\n";
    let doc = toml::parse(text).unwrap();
    let mut accel = presets::streamdcim_default();
    toml::apply_accel_overrides(&mut accel, &doc);
    assert_eq!(accel.arrays_per_macro, 4);
    assert_eq!(accel.array_cols, 64);
    assert_eq!(accel.features.mode_policy, streamdcim::cim::ModePolicy::ForcedHybrid);
    assert_eq!(accel.geometry().rows(), 4 * accel.array_rows);
    assert_eq!(accel.geometry().cols, 64);
}

#[test]
fn usage_mentions_every_command() {
    for cmd in ["run", "sweep", "trace", "perf-gate", "report", "serve", "dse", "config",
        "artifacts"]
    {
        assert!(cli::USAGE.contains(cmd), "USAGE missing {cmd}");
    }
    // the serving fabric's knobs are documented
    for flag in ["--shards", "--policy", "--arrival", "--matrix", "--gap"] {
        assert!(cli::USAGE.contains(flag), "USAGE missing {flag}");
    }
    // ... and the design-space explorer's
    for flag in ["--objectives", "--budget", "--frontier-out"] {
        assert!(cli::USAGE.contains(flag), "USAGE missing {flag}");
    }
    assert!(cli::USAGE.contains("frontier"), "USAGE missing the frontier figure");
}

#[test]
fn deprecated_hybrid_mode_alias_warns_and_round_trips_to_mode_policy() {
    // regression (PR 5): the legacy bool must (a) keep steering the mode
    // policy, (b) produce exactly one stderr warning line per load (the
    // default apply_accel_overrides prints what this returns), and
    // (c) round-trip to the named mode_policy key when the merged config
    // is re-serialized — the alias must never survive a round trip.
    let doc = toml::parse("[features]\nhybrid_mode = false\n").unwrap();
    let mut accel = presets::streamdcim_default();
    let warnings = toml::apply_accel_overrides_warnings(&mut accel, &doc);
    assert_eq!(warnings.len(), 1, "one warning line, got {warnings:?}");
    assert!(warnings[0].contains("hybrid_mode") && warnings[0].contains("deprecated"));
    assert_eq!(accel.features.mode_policy, streamdcim::cim::ModePolicy::ForcedNormal);

    let rendered = toml::render_accel(&accel);
    assert!(rendered.contains("mode_policy = \"normal\""));
    assert!(!rendered.contains("hybrid_mode"), "alias leaked into serialization");

    // the canonical form loads back warning-free and bit-equal
    let doc2 = toml::parse(&rendered).unwrap();
    let mut accel2 = presets::streamdcim_default();
    assert!(toml::apply_accel_overrides_warnings(&mut accel2, &doc2).is_empty());
    assert_eq!(accel2, accel);

    // hybrid_mode = true maps to auto and also warns
    let doc3 = toml::parse("[features]\nhybrid_mode = true\n").unwrap();
    let mut accel3 = presets::streamdcim_default();
    let w3 = toml::apply_accel_overrides_warnings(&mut accel3, &doc3);
    assert_eq!(w3.len(), 1);
    assert_eq!(accel3.features.mode_policy, streamdcim::cim::ModePolicy::Auto);
    assert!(toml::render_accel(&accel3).contains("mode_policy = \"auto\""));
}

#[test]
fn serving_config_round_trips_through_toml() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let text = std::fs::read_to_string(dir.join("serving_fabric.toml")).unwrap();
    let doc = toml::parse(&text).unwrap();
    let mut accel = presets::streamdcim_default();
    toml::apply_accel_overrides(&mut accel, &doc);
    assert_eq!(accel.serving.shards, 4);
    assert_eq!(accel.serving.queue_depth, 32);
    assert_eq!(accel.serving.batch_size, 8);
    assert_eq!(accel.serving.arrival_seed, 7);
    assert_eq!(accel.serving.policy, streamdcim::config::RoutePolicy::LeastLoaded);
}
