//! Property-based tests on coordinator/simulator invariants, using the
//! in-repo propcheck kit (deterministic, replayable by seed).

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::config::{presets, DataflowKind, PruningSchedule};
use streamdcim::model::refimpl::{self, Mat};
use streamdcim::model::{Op, OpKind, Stream};
use streamdcim::prop_assert;
use streamdcim::propcheck::Prop;
use streamdcim::pruning::PruningPolicy;
use streamdcim::sim::dtpu::top_k_indices;
use streamdcim::sim::{OpTiling, Timeline};
use streamdcim::util::json::Json;
use streamdcim::util::prng::Rng;

#[test]
fn prop_topk_kept_scores_dominate_dropped() {
    Prop::new("top-k keeps the k highest scores").cases(200).check(|rng| {
        let n = rng.range_usize(1, 64);
        let k = rng.range_usize(0, n);
        let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let kept = top_k_indices(&scores, k);
        prop_assert!(kept.len() == k, "kept {} != {k}", kept.len());
        prop_assert!(kept.windows(2).all(|w| w[0] < w[1]), "not sorted: {kept:?}");
        let dropped: Vec<usize> = (0..n).filter(|i| !kept.contains(i)).collect();
        if let (Some(&min_k), Some(&max_d)) = (
            kept.iter().min_by(|a, b| scores[**a].total_cmp(&scores[**b])),
            dropped.iter().max_by(|a, b| scores[**a].total_cmp(&scores[**b])),
        ) {
            prop_assert!(
                scores[min_k] >= scores[max_d],
                "kept min {} < dropped max {}",
                scores[min_k],
                scores[max_d]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_timeline_never_overlaps_and_busy_is_conserved() {
    Prop::new("timeline acquisitions are disjoint and ordered").cases(100).check(|rng| {
        let mut t = Timeline::with_trace("x");
        let mut total = 0u64;
        for _ in 0..rng.range_usize(1, 40) {
            let earliest = rng.range_u64(0, 1000);
            let dur = rng.range_u64(0, 50);
            let (s, e) = t.acquire(earliest, dur, "seg");
            prop_assert!(s >= earliest, "started early");
            prop_assert!(e - s == dur, "wrong duration");
            total += dur;
        }
        prop_assert!(t.busy_cycles() == total, "busy {} != {total}", t.busy_cycles());
        let segs = t.segments.as_ref().unwrap();
        for w in segs.windows(2) {
            prop_assert!(w[0].1 <= w[1].0, "segments overlap: {w:?}");
        }
        Ok(())
    });
}

#[test]
fn prop_tiling_covers_shape() {
    Prop::new("tiling covers the stationary operand exactly").cases(150).check(|rng| {
        let cfg = presets::streamdcim_default();
        let op = Op {
            name: "op",
            kind: OpKind::MatMulDynamic,
            stream: Stream::X,
            batch: rng.range_u64(1, 16),
            m: rng.range_u64(1, 512),
            k: rng.range_u64(1, 1024),
            n: rng.range_u64(1, 1024),
            bits: *[8u64, 16].get(rng.range_usize(0, 1)).unwrap(),
        };
        let t = OpTiling::of(&cfg, &op);
        // tiles cover k x n per batch element
        prop_assert!(
            t.k_tiles * 32 >= op.k && t.n_tiles * 128 >= op.n,
            "tiles too few: {t:?}"
        );
        prop_assert!(t.tiles == op.batch * t.k_tiles * t.n_tiles, "tile count");
        prop_assert!(t.passes(8) >= 1 && t.passes(8) <= t.tiles, "passes bound");
        prop_assert!(
            t.rewrite_cycles(&cfg) >= t.rewrite_cycles_for_pass(&cfg, 0, 8),
            "pass <= total"
        );
        let per_pass_sum: u64 =
            (0..t.passes(8)).map(|p| t.rewrite_cycles_for_pass(&cfg, p, 8)).sum();
        prop_assert!(
            per_pass_sum == t.rewrite_cycles(&cfg),
            "exact per-pass rewrites must sum to the total: {per_pass_sum} vs {}",
            t.rewrite_cycles(&cfg)
        );
        Ok(())
    });
}

#[test]
fn prop_pruning_policy_monotonic_and_bounded() {
    Prop::new("pruning targets are monotone, bounded, stage-aligned").cases(150).check(|rng| {
        let stages = vec![128u64, 96, 64];
        let policy = PruningPolicy::new(
            PruningSchedule {
                every: rng.range_u64(1, 3),
                keep_ratio: 0.5 + rng.f64() * 0.5,
                min_tokens: 64,
            },
            stages.clone(),
        );
        let n = rng.range_u64(64, 128);
        let layer = rng.range_u64(0, 5);
        let target = policy.target_tokens(n, layer);
        prop_assert!(target <= n.max(64), "grew: {n} -> {target}");
        prop_assert!(stages.contains(&target), "target {target} not a stage");
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    Prop::new("json emit/parse roundtrip").cases(100).check(|rng| {
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth > 2 { rng.range_usize(0, 4) } else { rng.range_usize(0, 6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.f64() < 0.5),
                // odd/16 is never integral (and exact in binary), so the
                // value reparses as Num rather than Int
                2 => Json::Num((rng.range_u64(0, 1_000_000) as f64) / 8.0 + 0.0625),
                // integer counters round-trip exactly, including >2^53
                3 => Json::int(rng.next_u64() >> rng.range_u64(0, 60)),
                4 => Json::Str(format!("s{}-\"quote\"\n", rng.range_u64(0, 99))),
                5 => Json::arr((0..rng.range_usize(0, 4)).map(|_| gen(rng, depth + 1)).collect()),
                _ => Json::obj(
                    vec![("a", gen(rng, depth + 1)), ("b", gen(rng, depth + 1))],
                ),
            }
        }
        let v = gen(rng, 0);
        let text = v.to_string_pretty();
        let back = Json::parse(&text).map_err(|e| format!("reparse: {e}"))?;
        prop_assert!(back == v, "roundtrip mismatch: {text}");
        Ok(())
    });
}

#[test]
fn prop_gather_rows_preserves_content() {
    Prop::new("DTPU gather keeps selected rows bit-identical").cases(100).check(|rng| {
        let rows = rng.range_usize(1, 32);
        let cols = rng.range_usize(1, 32);
        let m = Mat::random_i16_grid(rng, rows, cols, 1.0);
        let k = rng.range_usize(0, rows);
        let scores: Vec<f32> = (0..rows).map(|_| rng.f32()).collect();
        let idx = top_k_indices(&scores, k);
        let g = m.gather_rows(&idx);
        prop_assert!(g.rows == k, "rows {} != {k}", g.rows);
        for (new_r, &old_r) in idx.iter().enumerate() {
            prop_assert!(g.row(new_r) == m.row(old_r), "row {old_r} mutated");
        }
        Ok(())
    });
}

#[test]
fn prop_softmax_rows_stochastic() {
    Prop::new("refimpl softmax rows are stochastic").cases(80).check(|rng| {
        let rows = rng.range_usize(1, 16);
        let cols = rng.range_usize(1, 64);
        let mut m = Mat::random_i16_grid(rng, rows, cols, 5.0);
        refimpl::softmax_rows(&mut m);
        for r in 0..rows {
            let s: f32 = m.row(r).iter().sum();
            prop_assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
            prop_assert!(m.row(r).iter().all(|v| *v >= 0.0 && v.is_finite()), "bad probs");
        }
        Ok(())
    });
}

#[test]
fn prop_event_engine_dominates_analytic_lower_bounds() {
    // the event engine may schedule more conservatively than the analytic
    // model, but it can never beat the serial work floor of any single
    // resource — and both backends must agree exactly on total work
    Prop::new("event makespan >= per-resource work floors").cases(8).check(|rng| {
        let cfg = presets::streamdcim_default();
        let mut model = presets::functional_small();
        model.tokens_x = rng.range_u64(1, 96);
        model.tokens_y = rng.range_u64(1, 96);
        model.single_layers_x = rng.range_u64(0, 1);
        model.single_layers_y = rng.range_u64(0, 1);
        model.cross_layers = rng.range_u64(1, 2);
        model.pruning = PruningSchedule::disabled();
        for kind in DataflowKind::ALL {
            let graph = streamdcim::dataflow::graph_for(kind, &cfg, &model);
            let dyn_macros =
                streamdcim::cim::ModeSchedule::derive(kind, &cfg).dynamic_plan().active;
            let dyn_floor: u64 = graph
                .ops()
                .filter(|o| o.kind == OpKind::MatMulDynamic)
                .map(|o| OpTiling::of(&cfg, o).compute_cycles(dyn_macros))
                .sum();
            let sfu_floor: u64 = graph
                .ops()
                .map(|o| streamdcim::sim::sfu::sfu_cost(&cfg, o).0)
                .sum();
            let eng = streamdcim::engine::run(kind, &cfg, &model);
            let ana = streamdcim::dataflow::run(kind, &cfg, &model);
            prop_assert!(
                eng.cycles >= dyn_floor,
                "{kind:?}: engine {} < dynamic-matmul floor {dyn_floor}",
                eng.cycles
            );
            prop_assert!(
                eng.cycles >= sfu_floor,
                "{kind:?}: engine {} < SFU floor {sfu_floor}",
                eng.cycles
            );
            prop_assert!(
                eng.activity == ana.activity,
                "{kind:?}: engine and analytic disagree on total work"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_backends_agree_on_activity_across_mode_geometry_dataflow() {
    // analytic and event must produce identical Activity — macs,
    // cim_write_bits, tbsn_bits, occupancy ledger — over the full
    // macro-mode x geometry x dataflow matrix, including ragged token
    // counts that defy the macro geometry
    use streamdcim::cim::ModePolicy;
    Prop::new("backend Activity identical across mode x geometry x dataflow").cases(6).check(
        |rng| {
            let mut cfg = presets::streamdcim_default();
            cfg.features.mode_policy =
                ModePolicy::ALL[rng.range_usize(0, ModePolicy::ALL.len() - 1)];
            cfg.features.pingpong = rng.f64() < 0.5;
            cfg.arrays_per_macro = [4u64, 8, 16][rng.range_usize(0, 2)];
            cfg.array_cols = [64u64, 128, 256][rng.range_usize(0, 2)];
            cfg.macro_write_port_bits = [64u64, 128][rng.range_usize(0, 1)];
            let mut model = presets::functional_small();
            model.tokens_x = rng.range_u64(17, 90);
            model.tokens_y = rng.range_u64(17, 90);
            model.single_layers_x = 0;
            model.single_layers_y = 0;
            model.cross_layers = 1;
            model.pruning = PruningSchedule::disabled();
            for kind in DataflowKind::ALL {
                let ana = streamdcim::dataflow::run(kind, &cfg, &model);
                let eng = streamdcim::engine::run(kind, &cfg, &model);
                prop_assert!(
                    ana.activity == eng.activity,
                    "{kind:?}/{:?}: backends disagree ({:?} vs {:?})",
                    cfg.features.mode_policy,
                    ana.activity,
                    eng.activity
                );
                prop_assert!(
                    ana.activity.occupancy.used_cell_cycles > 0,
                    "{kind:?}: no occupancy recorded"
                );
                prop_assert!(
                    (ana.intra_macro_utilization() - eng.intra_macro_utilization()).abs()
                        < 1e-15,
                    "{kind:?}: utilization diverged"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_tile_never_slower_than_event_layer() {
    // the engine must preserve the paper's ordering on random workloads
    Prop::new("event tile <= event layer cycles").cases(6).check(|rng| {
        let cfg = presets::streamdcim_default();
        let mut model = presets::functional_small();
        model.tokens_x = 32 * rng.range_u64(1, 8);
        model.tokens_y = 32 * rng.range_u64(1, 8);
        model.cross_layers = rng.range_u64(1, 2);
        model.pruning = PruningSchedule::disabled();
        let layer = streamdcim::engine::run(DataflowKind::LayerStream, &cfg, &model).cycles;
        let tile = streamdcim::engine::run(DataflowKind::TileStream, &cfg, &model).cycles;
        prop_assert!(
            tile <= layer,
            "event tile {tile} > layer {layer} on {}x{}",
            model.tokens_x,
            model.tokens_y
        );
        Ok(())
    });
}

#[test]
fn prop_tile_stream_never_slower_than_layer_stream() {
    // routing/batching/state invariant of the coordinator's scheduling
    // choice: on any workload shape, tile streaming must not lose.
    Prop::new("tile <= layer cycles on random workloads").cases(12).check(|rng| {
        let cfg = presets::streamdcim_default();
        let mut model = presets::vilbert_base();
        model.tokens_x = 256 * rng.range_u64(1, 16);
        model.tokens_y = 256 * rng.range_u64(1, 16);
        model.d_model = 256 * rng.range_u64(1, 4);
        model.heads = model.d_model / 64;
        model.d_ff = model.d_model * 4;
        model.single_layers_x = rng.range_u64(0, 2);
        model.single_layers_y = rng.range_u64(0, 2);
        model.cross_layers = rng.range_u64(1, 3);
        model.pruning = PruningSchedule::disabled();
        let layer = streamdcim::dataflow::run(DataflowKind::LayerStream, &cfg, &model).cycles;
        let tile = streamdcim::dataflow::run(DataflowKind::TileStream, &cfg, &model).cycles;
        prop_assert!(
            tile <= layer,
            "tile {tile} > layer {layer} on {}x{} d{}",
            model.tokens_x,
            model.tokens_y,
            model.d_model
        );
        Ok(())
    });
}

#[test]
fn prop_schedule_cache_pricing_is_bit_identical_to_cold() {
    // the content-addressed schedule cache behind serve::CostModel must
    // be invisible: whatever (geometry x mode policy x dataflow x
    // serving) point asks, the cached BatchCost is the bit-identical
    // value a cold pricing produces — and serving knobs never change
    // the price (they are neutralized out of the cache key)
    use streamdcim::cim::ModePolicy;
    use streamdcim::config::{RoutePolicy, SchedulerKind, TenantConfig};
    use streamdcim::engine::Backend;
    use streamdcim::serve::{cost, CostModel};
    Prop::new("schedule cache = cold pricing, bitwise").cases(6).check(|rng| {
        let mut cfg = presets::streamdcim_default();
        cfg.features.mode_policy =
            ModePolicy::ALL[rng.range_usize(0, ModePolicy::ALL.len() - 1)];
        cfg.arrays_per_macro = [4u64, 8, 16][rng.range_usize(0, 2)];
        cfg.array_cols = [64u64, 128, 256][rng.range_usize(0, 2)];
        cfg.macro_write_port_bits = [64u64, 128][rng.range_usize(0, 1)];
        // randomized serving knobs — none of them may move the price
        cfg.serving.shards = rng.range_u64(1, 8);
        cfg.serving.batch_size = rng.range_u64(1, 16);
        cfg.serving.policy = RoutePolicy::ALL[rng.range_usize(0, RoutePolicy::ALL.len() - 1)];
        cfg.serving.scheduler = SchedulerKind::ALL[rng.range_usize(0, 1)];
        if rng.f64() < 0.5 {
            cfg.serving.tenants = vec![
                TenantConfig {
                    name: "interactive".into(),
                    weight: rng.range_u64(1, 4),
                    slo_cycles: 100_000,
                },
                TenantConfig { name: "batch".into(), weight: 1, slo_cycles: 0 },
            ];
        }
        let model = presets::tiny_smoke();
        let dataflow = DataflowKind::ALL[rng.range_usize(0, DataflowKind::ALL.len() - 1)];
        for backend in [Backend::Analytic, Backend::Event] {
            let cold = cost::price_uncached(&cfg, dataflow, backend, &model);
            // first call may populate the shared cache, second must hit it;
            // a serving-knob permutation must address the same entry
            let warm = CostModel::new(cfg.clone(), dataflow, backend).cost(&model);
            let mut permuted = cfg.clone();
            permuted.serving.shards = cfg.serving.shards % 8 + 1;
            permuted.serving.tenants.clear();
            let hit = CostModel::new(permuted, dataflow, backend).cost(&model);
            for c in [&warm, &hit] {
                prop_assert!(
                    c.first == cold.first
                        && c.per_extra == cold.per_extra
                        && c.warm_first == cold.warm_first
                        && c.reuse_write_bits == cold.reuse_write_bits,
                    "{dataflow:?}/{backend:?}: cycle fields diverged from cold pricing"
                );
                prop_assert!(
                    c.energy_mj.to_bits() == cold.energy_mj.to_bits(),
                    "{dataflow:?}/{backend:?}: energy bits diverged"
                );
                prop_assert!(
                    c.intra_macro_utilization.to_bits()
                        == cold.intra_macro_utilization.to_bits(),
                    "{dataflow:?}/{backend:?}: utilization bits diverged"
                );
                prop_assert!(
                    c.rewrite_hidden.map(f64::to_bits)
                        == cold.rewrite_hidden.map(f64::to_bits),
                    "{dataflow:?}/{backend:?}: rewrite_hidden bits diverged"
                );
                prop_assert!(
                    c.occupancy == cold.occupancy,
                    "{dataflow:?}/{backend:?}: occupancy ledger diverged"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pareto_frontier_subset_order_invariant_matches_bruteforce() {
    use streamdcim::dse::pareto;
    Prop::new("pareto frontier properties").cases(120).check(|rng| {
        let n = rng.range_usize(1, 24);
        let k = rng.range_usize(1, 4);
        // a coarse integer grid so duplicates and exact ties occur often
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| (0..k).map(|_| rng.range_u64(0, 4) as f64).collect()).collect();
        let frontier = pareto::frontier_indices(&pts);

        // frontier(points) ⊆ points: valid, unique, ascending indices
        prop_assert!(!frontier.is_empty(), "a finite non-empty set has a frontier");
        prop_assert!(frontier.iter().all(|&i| i < n), "index out of range: {frontier:?}");
        prop_assert!(
            frontier.windows(2).all(|w| w[0] < w[1]),
            "indices not strictly ascending: {frontier:?}"
        );

        // matches an independently-written brute-force O(n^2) dominance
        // check (strict dominance spelled out, not via pareto::dominates)
        for i in 0..n {
            let brute_dominated = pts.iter().any(|q| {
                q.iter().zip(&pts[i]).all(|(a, b)| a <= b)
                    && q.iter().zip(&pts[i]).any(|(a, b)| a < b)
            });
            prop_assert!(
                frontier.contains(&i) == !brute_dominated,
                "point {i} ({:?}): frontier membership {} vs brute-force dominated {}",
                pts[i],
                frontier.contains(&i),
                brute_dominated
            );
            prop_assert!(
                (pareto::dominated_by(&pts, i) > 0) == brute_dominated,
                "dominated_by disagrees with brute force on point {i}"
            );
        }

        // mutation-order invariance: shuffling the input never changes
        // the frontier *set* (compared as sorted cost vectors)
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let shuffled: Vec<Vec<f64>> = perm.iter().map(|&i| pts[i].clone()).collect();
        let f2 = pareto::frontier_indices(&shuffled);
        let sorted = |ixs: &[usize], set: &[Vec<f64>]| {
            let mut v: Vec<Vec<f64>> = ixs.iter().map(|&i| set[i].clone()).collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            v
        };
        prop_assert!(
            sorted(&frontier, &pts) == sorted(&f2, &shuffled),
            "frontier set changed under permutation"
        );
        Ok(())
    });
}
