//! Event-engine acceptance: determinism (threads, shard seeds, event
//! insertion orders), the three-way pipeline ordering over the full
//! scenario matrix, and the paper-plausible headline band — all on the
//! discrete-event backend (`sweep --engine event`).

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::config::{presets, DataflowKind};
use streamdcim::engine::{self, Backend};
use streamdcim::sweep;
use streamdcim::util::json::Json;

#[test]
fn full_event_matrix_ordering_band_and_thread_determinism() {
    let scenarios = sweep::full_matrix_backend(&presets::streamdcim_default(), Backend::Event);
    assert!(scenarios.len() >= 80, "matrix has only {}", scenarios.len());

    let serial = sweep::run_sweep(&scenarios, 1, 42);
    let parallel = sweep::run_sweep(&scenarios, 8, 42);
    assert_eq!(
        serial.to_json().to_string_pretty(),
        parallel.to_json().to_string_pretty(),
        "engine sweep must be bit-identical across --threads 1 vs --threads 8"
    );

    // aggregate JSON declares the backend
    let parsed = Json::parse(&serial.to_json().to_string_pretty()).unwrap();
    assert_eq!(parsed.get("engine").and_then(|e| e.as_str()), Some("event"));

    // per model: tile-streaming <= layer-streaming <= non-streaming
    let cycles = |model: &str, df: DataflowKind| -> u64 {
        serial
            .rows
            .iter()
            .find(|r| {
                r.result.report.model == model
                    && r.result.report.dataflow == df
                    && r.result.ablation == "full"
            })
            .unwrap_or_else(|| panic!("{model} missing {df:?}/full"))
            .result
            .report
            .cycles
    };
    let mut models: Vec<&str> = Vec::new();
    for r in &serial.rows {
        let name = r.result.report.model.as_str();
        if !models.contains(&name) {
            models.push(name);
        }
    }
    assert!(models.len() >= 10);
    for m in &models {
        let (non, layer, tile) = (
            cycles(m, DataflowKind::NonStream),
            cycles(m, DataflowKind::LayerStream),
            cycles(m, DataflowKind::TileStream),
        );
        assert!(tile <= layer, "{m}: tile {tile} > layer {layer}");
        assert!(layer <= non, "{m}: layer {layer} > non {non}");
    }

    // headline band on the attention presets (paper: 2.63x vs non-stream)
    let att = serial.headline.tile_vs_non_speedup_attention;
    assert!(att > 1.3, "attention-preset tile-vs-non speedup {att:.2} below plausible band");
    assert!(att < 8.0, "attention-preset tile-vs-non speedup {att:.2} above plausible band");
    let h = parsed.get("headline").expect("headline in aggregate");
    let att_json = h.get("tile_vs_non_speedup_attention").and_then(|v| v.as_f64()).unwrap();
    assert!((att_json - att).abs() < 1e-9);

    // every event row carries its trace summary
    for row in parsed.get("scenarios").unwrap().as_arr().unwrap() {
        assert!(row.get("engine_trace").is_some(), "row missing engine_trace");
    }
}

#[test]
fn small_event_matrix_is_seed_invariant() {
    let scenarios = sweep::matrix_for_backend(
        &presets::streamdcim_default(),
        &[presets::tiny_smoke(), presets::functional_small()],
        Backend::Event,
    );
    let a = sweep::run_sweep(&scenarios, 3, 1).to_json().to_string_pretty();
    let b = sweep::run_sweep(&scenarios, 3, 999).to_json().to_string_pretty();
    assert_eq!(a, b, "shard-shuffle seed must not change the event aggregate");
}

#[test]
fn event_heap_insertion_order_is_irrelevant() {
    // mirror tests/sweep_determinism.rs at the event level: seeded
    // shuffles of the initial poll and completion fan-out must be
    // bit-identical to the canonical order, for every dataflow
    let cfg = presets::streamdcim_default();
    for model in [presets::tiny_smoke(), presets::functional_small()] {
        for kind in DataflowKind::ALL {
            let sched = engine::schedule::build(kind, &cfg, &model);
            let base = engine::event::simulate_traced(&sched);
            for seed in [7u64, 42, 0xDEAD_BEEF] {
                let alt = engine::event::simulate_shuffled(&sched, seed);
                assert_eq!(base.makespan, alt.makespan, "{}/{kind:?}/{seed}", model.name);
                assert_eq!(base.start, alt.start, "{}/{kind:?}/{seed}", model.name);
                assert_eq!(base.end, alt.end, "{}/{kind:?}/{seed}", model.name);
                assert_eq!(base.exposed, alt.exposed, "{}/{kind:?}/{seed}", model.name);
                assert_eq!(base.busy, alt.busy, "{}/{kind:?}/{seed}", model.name);
                assert_eq!(base.stall, alt.stall, "{}/{kind:?}/{seed}", model.name);
                assert_eq!(base.segments, alt.segments, "{}/{kind:?}/{seed}", model.name);
            }
        }
    }
}

#[test]
fn engine_feature_ablations_still_cost_performance() {
    // the paper's mechanisms must each contribute under the event engine
    let scenarios = sweep::matrix_for_backend(
        &presets::streamdcim_default(),
        &[presets::vilbert_base()],
        Backend::Event,
    );
    let report = sweep::run_sweep(&scenarios, 4, 42);
    let speed = |ablation: &str| {
        report
            .rows
            .iter()
            .find(|r| {
                r.result.report.dataflow == DataflowKind::TileStream
                    && r.result.ablation == ablation
            })
            .map(|r| r.speedup_vs_non)
            .unwrap()
    };
    let full = speed("full");
    for ablation in ["no-pruning", "no-pingpong", "no-hybrid", "forced-hybrid"] {
        assert!(
            speed(ablation) < full,
            "{ablation} ({:.3}) should lose to full ({full:.3})",
            speed(ablation)
        );
    }
}
