//! Integration tests for the streaming artifact layer: the push writer
//! must be byte-identical to the tree serializer, the pull parser must
//! rebuild the exact tree (faithful integers included), malformed input
//! must error instead of panicking, and the two reader-powered features
//! (serve-trace replay, streaming perf-gate diff) must reproduce their
//! tree-built counterparts exactly.

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::artifact::reader::MAX_DEPTH;
use streamdcim::artifact::{parse_line, JsonReader, JsonWriter, JsonlWriter};
use streamdcim::config::{presets, DataflowKind};
use streamdcim::engine::Backend;
use streamdcim::perfgate;
use streamdcim::prop_assert;
use streamdcim::propcheck::Prop;
use streamdcim::serve::{self, ArrivalKind, ServeConfig};
use streamdcim::sweep;
use streamdcim::util::json::Json;
use streamdcim::util::prng::Rng;

/// Arbitrary JSON tree. The float arm is never integral (k/8 + 1/16) so
/// `Num` and `Int` stay distinguishable through a round-trip; the int
/// arm spans the full u64 range (well past 2^53) plus negatives.
fn gen(rng: &mut Rng, depth: usize) -> Json {
    // range_usize is inclusive; past depth 3 only scalar arms remain
    let top = if depth >= 3 { 4 } else { 6 };
    match rng.range_usize(0, top) {
        0 => Json::Null,
        1 => Json::Bool(rng.next_u64() % 2 == 0),
        2 => Json::num((rng.range_u64(0, 1 << 20) as f64) / 8.0 + 0.0625),
        3 => {
            let v = rng.next_u64() >> rng.range_u64(0, 60);
            if rng.next_u64() % 4 == 0 {
                Json::int(-(v as i128))
            } else {
                Json::int(v)
            }
        }
        4 => {
            const POOL: &[&str] = &[
                "",
                "plain",
                "quote\"backslash\\",
                "tab\tnewline\ncr\r",
                "unicode-\u{3b1}\u{1f980}",
                "ctrl-\u{1}\u{1f}",
            ];
            Json::str(POOL[rng.range_usize(0, POOL.len() - 1)])
        }
        5 => {
            let n = rng.range_usize(0, 4);
            Json::arr((0..n).map(|_| gen(rng, depth + 1)).collect())
        }
        _ => {
            let n = rng.range_usize(0, 4);
            let mut m = std::collections::BTreeMap::new();
            for _ in 0..n {
                m.insert(format!("k{}", rng.range_usize(0, 8)), gen(rng, depth + 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_streamed_bytes_match_the_tree_serializer_and_reparse() {
    Prop::new("stream writer == to_string_pretty; pull parser rebuilds the tree")
        .cases(200)
        .check(|rng| {
            let tree = gen(rng, 0);

            // push-streamed pretty document == the tree serializer, byte for byte
            let mut pretty = Vec::new();
            JsonWriter::pretty(&mut pretty)
                .value(&tree)
                .map_err(|e| format!("pretty write: {e}"))?;
            let pretty = String::from_utf8(pretty).map_err(|e| format!("utf8: {e}"))?;
            prop_assert!(
                pretty == tree.to_string_pretty(),
                "streamed pretty bytes diverge from the tree serializer"
            );

            // the pull parser rebuilds the identical tree from those bytes
            let mut r = JsonReader::new(&pretty);
            let back = r
                .read_value()
                .map_err(|e| format!("pull parse: {} at byte {}", e.msg, e.pos))?;
            let trailing = r
                .next_event()
                .map_err(|e| format!("trailing check: {} at byte {}", e.msg, e.pos))?;
            prop_assert!(trailing.is_none(), "events after the document end");
            prop_assert!(back == tree, "pull-parsed tree mismatch");

            // compact row: exactly one line, same tree back via parse_line
            let mut row = Vec::new();
            JsonlWriter::new(&mut row)
                .value(&tree)
                .map_err(|e| format!("row write: {e}"))?;
            let row = String::from_utf8(row).map_err(|e| format!("utf8: {e}"))?;
            prop_assert!(row.ends_with('\n'), "row must be newline-terminated");
            prop_assert!(
                !row.trim_end_matches('\n').contains('\n'),
                "row must be a single physical line"
            );
            let back = parse_line(row.trim_end_matches('\n'))
                .map_err(|e| format!("parse_line: {} at byte {}", e.msg, e.pos))?;
            prop_assert!(back == tree, "jsonl row roundtrip mismatch");
            Ok(())
        });
}

#[test]
fn counters_above_2_53_roundtrip_losslessly() {
    let sentinel = (1u64 << 53) + 1; // first u64 the f64 path cannot represent
    assert_ne!((sentinel as f64) as u64, sentinel, "regression premise: f64 rounds it");
    let row = Json::obj(vec![
        ("macs", Json::int(sentinel)),
        ("total_cycles", Json::int(u64::MAX)),
    ]);

    let mut buf = Vec::new();
    JsonlWriter::new(&mut buf).value(&row).unwrap();
    let text = String::from_utf8(buf).unwrap();
    assert!(text.contains("9007199254740993"), "{text}");
    assert!(text.contains("18446744073709551615"), "{text}");

    let back = parse_line(text.trim_end()).unwrap();
    assert_eq!(back.get("macs").and_then(|v| v.as_u64()), Some(sentinel));
    assert_eq!(back.get("total_cycles").and_then(|v| v.as_u64()), Some(u64::MAX));
    assert_eq!(back, row);

    // the pretty document is just as faithful, and the tree parser agrees
    let mut pretty = Vec::new();
    JsonWriter::pretty(&mut pretty).value(&row).unwrap();
    let pretty = String::from_utf8(pretty).unwrap();
    assert_eq!(pretty, row.to_string_pretty());
    assert_eq!(Json::parse(&pretty).unwrap(), row);
}

/// Drive the pull parser to completion; true iff it errored.
fn pull_errors(src: &str) -> bool {
    let mut r = JsonReader::new(src);
    loop {
        match r.next_event() {
            Err(_) => return true,
            Ok(None) => return false,
            Ok(Some(_)) => {}
        }
    }
}

#[test]
fn malformed_input_errors_instead_of_panicking() {
    let bad = [
        "{",
        "[",
        "{\"a\":",
        "{\"a\":1,}",
        "[1,]",
        "[1 2]",
        "{\"a\" 1}",
        "tru",
        "nul",
        "-",
        "1e",
        "\"unterminated",
        "\"bad escape \\q\"",
        "{\"a\":1}}",
        "[]extra",
    ];
    for src in bad {
        assert!(pull_errors(src), "pull reader accepted {src:?}");
        assert!(parse_line(src).is_err(), "parse_line accepted {src:?}");
        assert!(Json::parse(src).is_err(), "tree parser accepted {src:?}");
    }

    // hostile nesting: a positioned error, not a stack overflow
    let deep = "[".repeat(MAX_DEPTH * 4);
    assert!(pull_errors(&deep));
    assert!(parse_line(&deep).is_err());
    assert!(Json::parse(&deep).is_err());

    // legal nesting well under the bound still parses
    let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
    assert!(!pull_errors(&ok));
    assert!(parse_line(&ok).is_ok());

    // the replay reader reports structured, line-positioned errors
    assert!(serve::read_trace("").is_err(), "no header");
    assert!(serve::read_trace("{\"row\":\"request\",\"id\":0}\n").is_err(), "request first");
    assert!(serve::read_trace("{\"row\":\"header\",\"kind\":\"serve-trace\"").is_err());
}

fn serve_cfg(requests: u64) -> ServeConfig {
    let mut accel = presets::streamdcim_default();
    accel.serving.shards = 3;
    let models = vec![presets::tiny_smoke(), presets::functional_small()];
    let mean_gap = serve::auto_gap(&accel, Backend::Analytic, &models);
    ServeConfig {
        accel,
        models,
        dataflow: DataflowKind::TileStream,
        backend: Backend::Analytic,
        arrival: ArrivalKind::Poisson,
        requests,
        mean_gap,
    }
}

#[test]
fn recorded_serve_trace_replays_bit_identically() {
    let cfg = serve_cfg(512);
    let events = serve::arrival_trace(&cfg);

    // record: the observer streams header + one request row per arrival
    let mut buf = Vec::new();
    let mut tw = serve::TraceWriter::begin(&mut buf, &cfg.config_json()).unwrap();
    let original = serve::simulate_trace(&cfg, &events, &mut tw).unwrap();
    drop(tw);
    let text = String::from_utf8(buf).unwrap();
    assert_eq!(
        text.lines().count() as u64,
        1 + cfg.requests,
        "one header row plus one request row per arrival"
    );

    // replay from the artifact alone (config comes from the header row)
    let trace = serve::read_trace(&text).unwrap();
    let replayed = trace.replay(presets::streamdcim_default()).unwrap();
    assert_eq!(original.stats, replayed.stats, "replay must reproduce ServeStats exactly");

    // the streamed report equals the tree serializer byte for byte
    let mut streamed = Vec::new();
    original.write_json(&mut streamed).unwrap();
    assert_eq!(String::from_utf8(streamed).unwrap(), original.to_json().to_string_pretty());

    // and every report row is a parseable tagged line
    let mut rows = Vec::new();
    original.write_jsonl(&mut rows).unwrap();
    let rows = String::from_utf8(rows).unwrap();
    assert!(!rows.is_empty());
    for line in rows.lines() {
        let row = parse_line(line).unwrap();
        assert!(row.get("row").and_then(|v| v.as_str()).is_some(), "untagged row: {line}");
    }
}

#[test]
fn stream_diff_agrees_with_the_tree_comparison() {
    let base: Vec<perfgate::GateEntry> = (0u64..12)
        .map(|i| perfgate::GateEntry { id: format!("scenario-{i:02}"), cycles: 1_000 + 37 * i })
        .collect();
    let mut cur = base.clone();
    cur[3].cycles = (1u64 << 53) + 7; // past f64 territory on purpose
    cur.push(perfgate::GateEntry { id: "added".into(), cycles: 5 });

    let mut a = Vec::new();
    perfgate::write_baseline(&mut a, &base, false).unwrap();
    let mut b = Vec::new();
    perfgate::write_baseline(&mut b, &cur, false).unwrap();
    let (a, b) = (String::from_utf8(a).unwrap(), String::from_utf8(b).unwrap());

    // pull-parsed diff == tree-built diff, down to the artifact bytes
    let streamed = perfgate::stream_diff(&a, &b, perfgate::DEFAULT_TOLERANCE).unwrap();
    let tree = perfgate::compare(&base, &cur, perfgate::DEFAULT_TOLERANCE);
    assert_eq!(streamed.to_json().to_string_pretty(), tree.to_json().to_string_pretty());

    // a baseline diffed against itself passes at exactly unity
    let unity = perfgate::stream_diff(&a, &a, perfgate::DEFAULT_TOLERANCE).unwrap();
    assert!(unity.pass, "self-diff must pass: {}", unity.verdict);
    assert!((unity.geomean_ratio - 1.0).abs() < 1e-12);
    assert!(unity.missing.is_empty() && unity.added.is_empty());
}

#[test]
fn sweep_aggregate_streams_byte_identically() {
    let accel = presets::streamdcim_default();
    let models = vec![presets::tiny_smoke()];
    let mut scenarios = sweep::matrix_for(&accel, &models);
    scenarios.truncate(4);
    let rep = sweep::run_sweep(&scenarios, 2, 42);

    let mut streamed = Vec::new();
    rep.write_json(&mut streamed).unwrap();
    assert_eq!(String::from_utf8(streamed).unwrap(), rep.to_json().to_string_pretty());

    let mut rows = Vec::new();
    rep.write_jsonl(&mut rows).unwrap();
    let rows = String::from_utf8(rows).unwrap();
    assert!(rows.lines().count() > scenarios.len(), "header plus one row per scenario");
    for line in rows.lines() {
        parse_line(line).unwrap();
    }
}
