//! Full Fig. 6 + Fig. 7 regeneration: both ViLBERT models, all three
//! dataflows, with the paper's numbers alongside for comparison —
//! the experiment driver behind EXPERIMENTS.md §E3/§E4/§E6.
//!
//! ```sh
//! cargo run --release --offline --example vilbert_sweep
//! ```

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::config::presets;
use streamdcim::report;

fn main() {
    let cfg = presets::streamdcim_default();
    let all: Vec<_> = [presets::vilbert_base(), presets::vilbert_large()]
        .into_iter()
        .map(|m| {
            println!("running {} (3 dataflows)...", m.name);
            (m.name.clone(), report::run_all(&cfg, &m))
        })
        .collect();

    for fig in [report::fig6(&all), report::fig7(&all), report::headline(&all)] {
        println!("\n=== {} ===\n{}", fig.title, fig.body);
    }

    // per-layer view of where Tile-stream wins on ViLBERT-base
    let base = &all[0].1;
    use streamdcim::config::DataflowKind;
    let layer = base.iter().find(|r| r.dataflow == DataflowKind::LayerStream).unwrap();
    let tile = base.iter().find(|r| r.dataflow == DataflowKind::TileStream).unwrap();
    println!("=== per-layer cycles, ViLBERT-base (Layer-stream vs Tile-stream) ===");
    println!(
        "{:<8} {:>14} {:>14} {:>9} {:>24}",
        "layer", "layer-stream", "tile-stream", "speedup", "exposed rewrite (layer)"
    );
    for (a, b) in layer.per_layer.iter().zip(&tile.per_layer) {
        println!(
            "{:<8} {:>14} {:>14} {:>8.2}x {:>24}",
            format!("{} {}", a.index, if a.label.contains("Cross") { "x" } else { "s" }),
            a.cycles(),
            b.cycles(),
            a.cycles() as f64 / b.cycles() as f64,
            a.exposed_rewrite
        );
    }
}
