//! Quickstart: simulate one ViLBERT-base run under all three dataflows,
//! print the comparison, and (if `make artifacts` has run) push one
//! cross-modal encoder block through the PJRT runtime.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use std::path::Path;

use streamdcim::config::presets;
use streamdcim::model::refimpl::{BlockWeights, Mat};
use streamdcim::report;
use streamdcim::runtime::Runtime;
use streamdcim::util::error::Result;
use streamdcim::util::prng::Rng;

fn main() -> Result<()> {
    // --- 1. the paper's headline experiment, one model -----------------
    let cfg = presets::streamdcim_default();
    let model = presets::vilbert_base();
    println!("simulating {} under all three dataflows...", model.name);
    let runs = report::run_all(&cfg, &model);
    for r in &runs {
        println!(
            "  {:<13} {:>12} cycles  {:>8.2} ms  {:>8.2} mJ",
            r.dataflow.name(),
            r.cycles,
            r.ms,
            r.energy.total_mj()
        );
    }
    let (s_non, s_layer) = report::speedups(&runs);
    println!(
        "  Tile-stream speedup: {s_non:.2}x vs Non-stream (paper 2.86x), \
         {s_layer:.2}x vs Layer-stream (paper 1.25x)"
    );

    // --- 2. one encoder block through the AOT artifacts ----------------
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\n(skipping PJRT demo — run `make artifacts` first)");
        return Ok(());
    }
    println!("\nloading AOT artifacts (jax/pallas -> HLO text -> PJRT)...");
    let rt = Runtime::load(dir)?;
    println!("  {} artifacts compiled", rt.artifact_names().len());

    let mut rng = Rng::new(42);
    let weights = BlockWeights::random(&mut rng, 128, 512);
    let vision = Mat::random_i16_grid(&mut rng, 128, 128, 0.5);
    let language = Mat::random_i16_grid(&mut rng, 128, 128, 0.5);
    let (out, scores) = rt.run_block("block_n128_d128_h4", &vision, &language, &weights)?;
    println!("  cross-modal block: {}x{} tokens out", out.rows, out.cols);

    // DTPU decision: which language tokens would survive pruning?
    let kept = streamdcim::sim::dtpu::top_k_indices(&scores, 96);
    println!(
        "  DTPU keeps 96/128 language tokens; top-3 by importance: {:?}",
        {
            let mut idx: Vec<usize> = (0..scores.len()).collect();
            idx.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
            idx[..3].to_vec()
        }
    );
    assert_eq!(kept.len(), 96);
    println!("quickstart OK");
    Ok(())
}
