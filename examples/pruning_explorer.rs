//! Pruning frontier explorer: sweep DTPU keep-ratios and report the
//! speedup / retained-attention-mass tradeoff (the Evo-ViT-style ">1.6x
//! at negligible accuracy loss" claim, experiment E7).
//!
//! "Accuracy proxy" = fraction of total attention probability mass carried
//! by the kept tokens, measured functionally on the reference stack — the
//! quantity column-mean ranking maximizes per step.
//!
//! ```sh
//! cargo run --release --offline --example pruning_explorer
//! ```

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use streamdcim::config::{presets, DataflowKind, PruningSchedule};
use streamdcim::coordinator::EncoderStack;
use streamdcim::dataflow;
use streamdcim::model::refimpl::{encoder_block, Mat};
use streamdcim::sim::dtpu::top_k_indices;
use streamdcim::util::prng::Rng;

fn main() {
    // functional measurement: how much attention mass do kept tokens carry?
    let model = presets::functional_small();
    let stack = EncoderStack::new(&model, vec![128, 96, 64], 11);
    let mut rng = Rng::new(3);
    let ix = Mat::random_i16_grid(&mut rng, 128, 128, 0.5);
    let iy = Mat::random_i16_grid(&mut rng, 128, 128, 0.5);
    let (wx, _) = &stack.weights[0];
    let (_, scores) = encoder_block(wx, &ix, &iy, 4);

    println!("== retained attention mass vs keep-ratio (first cross layer) ==");
    println!("{:>10} {:>8} {:>16}", "keep", "tokens", "mass retained");
    for keep in [1.0, 0.9, 0.75, 0.5, 0.25] {
        let k = (128.0 * keep) as usize;
        let kept = top_k_indices(&scores, k);
        let mass: f32 = kept.iter().map(|&i| scores[i]).sum();
        println!("{keep:>10.2} {k:>8} {:>15.1} %", mass * 100.0);
    }

    // architectural measurement: end-to-end speedup on ViLBERT-base
    println!("\n== end-to-end ViLBERT-base speedup vs keep-ratio ==");
    let cfg = presets::streamdcim_default();
    let mut no_prune = presets::vilbert_base();
    no_prune.pruning = PruningSchedule::disabled();
    let base = dataflow::run(DataflowKind::TileStream, &cfg, &no_prune).cycles as f64;
    println!("{:>10} {:>14} {:>10} {:>12}", "keep", "cycles", "speedup", "energy (mJ)");
    for keep in [0.9, 0.8, 0.75, 0.7, 0.6, 0.5] {
        let mut m = presets::vilbert_base();
        m.pruning = PruningSchedule { every: 1, keep_ratio: keep, min_tokens: 512 };
        let r = dataflow::run(DataflowKind::TileStream, &cfg, &m);
        println!(
            "{keep:>10.2} {:>14} {:>9.2}x {:>12.2}",
            r.cycles,
            base / r.cycles as f64,
            r.energy.total_mj()
        );
    }
    println!("\npaper reference point: pruning image-token redundancy -> >1.6x speedup");
}
