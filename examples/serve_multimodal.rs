//! END-TO-END DRIVER (deliverable (b)/E2E): serve batched multimodal
//! requests through the full three-layer stack, then push the same
//! workload class through the sharded serving fabric.
//!
//! * L1/L2: the encoder-block artifacts were authored as JAX + Pallas
//!   kernels and AOT-lowered to HLO text (`make artifacts`).
//! * L3 functional path: the Rust coordinator loads the artifacts via
//!   PJRT (falling back to the pure-Rust reference when they are
//!   absent), batches incoming requests, runs the ViLBERT-style
//!   cross-modal stack with DTPU token pruning between stages
//!   (128 -> 96 -> 64 tokens), and reports latency/throughput — with
//!   every batch additionally priced in engine cycles.
//! * L3 traffic path: the serving fabric replays a deterministic
//!   arrival trace through bounded queues, the continuous batcher, and
//!   policy-routed engine-priced shards, under all three dataflows.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example serve_multimodal
//! ```

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use std::path::PathBuf;
use std::time::Instant;

use streamdcim::config::{presets, DataflowKind};
use streamdcim::coordinator::{Coordinator, CoordinatorConfig, Request};
use streamdcim::engine::Backend;
use streamdcim::model::refimpl::Mat;
use streamdcim::serve::{self, ArrivalKind, ServeConfig};
use streamdcim::util::error::Result;
use streamdcim::util::prng::Rng;

fn main() -> Result<()> {
    let n_requests = 48u64;
    let batch = 6usize;
    let model = presets::functional_small();
    let artifacts = PathBuf::from("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists();

    println!("== StreamDCIM end-to-end serving driver ==");
    let mut cfg = CoordinatorConfig::reference(vec![128, 96, 64], batch, 42);
    if have_artifacts {
        println!("loading + compiling artifacts (PJRT CPU)...");
        cfg.artifact_dir = Some(artifacts);
    } else {
        println!("artifacts missing (`make artifacts`) — pure-rust reference path");
    }
    let t0 = Instant::now();
    let coord = Coordinator::start(cfg, &model)?;
    println!("leader ready in {:.2} s", t0.elapsed().as_secs_f64());

    // synthetic VQA-shaped workload: 128 vision tokens + 128 language
    // tokens per request, INT16-grid values (paper Sec. III-A analogue)
    let mut rng = Rng::new(7);
    let t1 = Instant::now();
    let waiters: Vec<_> = (0..n_requests)
        .map(|id| {
            coord.submit(Request {
                id,
                ix: Mat::random_i16_grid(&mut rng, 128, 128, 0.5),
                iy: Mat::random_i16_grid(&mut rng, 128, 128, 0.5),
            })
        })
        .collect();

    let mut pruned_to = 0;
    for w in waiters {
        let resp = w.recv().expect("leader alive")?;
        assert_eq!(resp.stages, vec![128, 96, 64]);
        assert!(resp.batch_sim_cycles > 0);
        pruned_to = resp.x.rows;
    }
    let wall = t1.elapsed();
    let stats = coord.shutdown();

    println!("\n-- functional serving results --");
    println!("requests      : {}", stats.served);
    println!("wall time     : {:.2} s", wall.as_secs_f64());
    println!("throughput    : {:.2} req/s", stats.served as f64 / wall.as_secs_f64());
    println!(
        "latency       : mean {:.1} ms   p50 {:.1} ms   p95 {:.1} ms   p99 {:.1} ms",
        stats.mean_latency_us() / 1e3,
        stats.percentile_us(0.5) as f64 / 1e3,
        stats.percentile_us(0.95) as f64 / 1e3,
        stats.percentile_us(0.99) as f64 / 1e3
    );
    println!("mean batch    : {:.2}", stats.mean_batch());
    println!(
        "engine cycles : {} total ({:.2} served per busy Mcycle on silicon)",
        stats.sim_cycles,
        stats.served_per_busy_megacycle()
    );
    if let Some(h) = stats.rewrite_hidden {
        println!("rewrite hidden: {:.1} %", h * 100.0);
    }
    println!("token pruning : 128 -> 96 -> 64 (final {} tokens/modality)", pruned_to);

    // --- the same workload class through the sharded fabric ------------
    println!("\n-- closed-loop traffic through the serving fabric --");
    let mut accel = presets::streamdcim_default();
    accel.serving.shards = 4;
    let models = vec![model];
    let mean_gap = serve::auto_gap(&accel, Backend::Event, &models);
    for dataflow in DataflowKind::ALL {
        let rep = serve::simulate(&ServeConfig {
            accel: accel.clone(),
            models: models.clone(),
            dataflow,
            backend: Backend::Event,
            arrival: ArrivalKind::Poisson,
            requests: 64,
            mean_gap,
        });
        let s = &rep.stats;
        println!(
            "  {:<13} {:>7.2} served/Mcycle   p99 {:>9} cycles   {:>3} rejected   \
             cim util {:>5.1} %",
            dataflow.name(),
            s.served_per_megacycle(),
            s.latency.p99(),
            s.rejected,
            s.intra_macro_utilization * 100.0
        );
        // per-shard intra-macro CIM utilization (cim::OccupancyLedger,
        // request-weighted) next to classic busy-time occupancy
        for (i, sh) in s.per_shard.iter().enumerate() {
            println!(
                "      shard {i}: {:>5.1} % busy   {:>4} served   intra-macro {:>5.1} %",
                sh.utilization(s.makespan) * 100.0,
                sh.served,
                sh.intra_macro_utilization() * 100.0
            );
        }
    }
    println!("\nserve_multimodal OK");
    Ok(())
}
