//! END-TO-END DRIVER (deliverable (b)/E2E): serve batched multimodal
//! requests through the full three-layer stack on a real small workload.
//!
//! * L1/L2: the encoder-block artifacts were authored as JAX + Pallas
//!   kernels and AOT-lowered to HLO text (`make artifacts`).
//! * L3: this binary starts the Rust coordinator, which loads the
//!   artifacts via PJRT, batches incoming requests, runs the ViLBERT-style
//!   cross-modal stack with DTPU token pruning between stages
//!   (128 -> 96 -> 64 tokens), and reports latency/throughput.
//! * The cycle-level simulator prices the same workload on StreamDCIM
//!   silicon, so every serving run also reports simulated accelerator
//!   latency/energy under all three dataflows.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example serve_multimodal
//! ```

// Same lint posture as lib.rs (authored offline without clippy in the loop).
#![allow(unknown_lints)]
#![allow(clippy::style, clippy::complexity)]

use std::path::PathBuf;
use std::time::Instant;

use streamdcim::config::presets;
use streamdcim::coordinator::{Coordinator, Request};
use streamdcim::ensure;
use streamdcim::model::refimpl::Mat;
use streamdcim::report;
use streamdcim::util::error::Result;
use streamdcim::util::prng::Rng;

fn main() -> Result<()> {
    let n_requests = 48u64;
    let batch = 6usize;
    let model = presets::functional_small();
    let artifacts = PathBuf::from("artifacts");
    ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    println!("== StreamDCIM end-to-end serving driver ==");
    println!("loading + compiling artifacts (PJRT CPU)...");
    let t0 = Instant::now();
    let coord = Coordinator::start(Some(artifacts), &model, vec![128, 96, 64], batch, 42)?;
    println!("leader ready in {:.2} s", t0.elapsed().as_secs_f64());

    // synthetic VQA-shaped workload: 128 vision tokens + 128 language
    // tokens per request, INT16-grid values (paper Sec. III-A analogue)
    let mut rng = Rng::new(7);
    let t1 = Instant::now();
    let waiters: Vec<_> = (0..n_requests)
        .map(|id| {
            coord.submit(Request {
                id,
                ix: Mat::random_i16_grid(&mut rng, 128, 128, 0.5),
                iy: Mat::random_i16_grid(&mut rng, 128, 128, 0.5),
            })
        })
        .collect();

    let mut pruned_to = 0;
    for w in waiters {
        let resp = w.recv().expect("leader alive")?;
        assert_eq!(resp.stages, vec![128, 96, 64]);
        pruned_to = resp.x.rows;
    }
    let wall = t1.elapsed();
    let stats = coord.shutdown();

    println!("\n-- serving results --");
    println!("requests      : {}", stats.served);
    println!("wall time     : {:.2} s", wall.as_secs_f64());
    println!("throughput    : {:.2} req/s", stats.served as f64 / wall.as_secs_f64());
    println!(
        "latency       : mean {:.1} ms   p50 {:.1} ms   p95 {:.1} ms",
        stats.mean_latency_us() / 1e3,
        stats.percentile_us(0.5) as f64 / 1e3,
        stats.percentile_us(0.95) as f64 / 1e3
    );
    println!("mean batch    : {:.2}", stats.mean_batch());
    println!("token pruning : 128 -> 96 -> 64 (final {} tokens/modality)", pruned_to);

    // --- what would this cost on StreamDCIM silicon? -------------------
    println!("\n-- simulated accelerator cost for the same workload --");
    let cfg = presets::streamdcim_default();
    let runs = report::run_all(&cfg, &model);
    for r in &runs {
        println!(
            "  {:<13} {:>10} cycles  {:>7.3} ms/request  {:>8.4} mJ/request",
            r.dataflow.name(),
            r.cycles,
            r.ms,
            r.energy.total_mj()
        );
    }
    let (s_non, s_layer) = report::speedups(&runs);
    println!("  Tile-stream: {s_non:.2}x vs Non-stream, {s_layer:.2}x vs Layer-stream");
    println!("\nserve_multimodal OK");
    Ok(())
}
